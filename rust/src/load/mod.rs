//! Open-loop load harness: drive the unlearning service at a target
//! request rate that does NOT slow down when the service falls behind.
//!
//! Every bench in this repo before this module was closed-loop — a
//! deterministic trace submitted round by round, so the offered load
//! implicitly waited for the service. That can never observe the thing
//! CAUSE's throughput claims are about: what happens when deletion
//! requests arrive *faster* than the energy envelope lets the device
//! retrain. This harness separates the arrival process from service
//! progress (chroma's load-crate shape: scenario trait objects, seeded
//! randomness, skewed selectors):
//!
//! * a [`Scenario`] describes the workload — population shape, battery
//!   and harvest schedule, per-tick arrival intensity, and how one
//!   deletion request is drawn (skewed user/key selection) from the
//!   remaining data;
//! * [`run_open_loop`] replays it at an offered rate: each tick the
//!   arrival schedule decides how many requests arrive (fractional
//!   rates accumulate), they are submitted whether or not the service
//!   kept up, the clock advances, harvest lands, and one batched drain
//!   runs. A bounded tail then lets the service finish queued and
//!   battery-parked work;
//! * latencies land in a log-bucketed [`LatencyHistogram`] (per shard
//!   in fleet mode, merged losslessly) rather than a p50/p99 pair;
//! * [`sweep`] walks offered rates to find the max rate at which the
//!   scenario still meets its SLO — the `rps_at_slo` number that
//!   `BENCH_load.json` reports and `bench_gate` gates.
//!
//! Everything is deterministic: seeded [`Rng`], logical ticks (no wall
//! clock), and an FNV-1a digest of the submitted request trace that
//! tests assert byte-stable across runs.

pub mod chaos;
pub mod hist;
pub mod scenarios;

use std::collections::BTreeMap;

use anyhow::Result;

use crate::config::ExperimentConfig;
use crate::coordinator::system::SystemVariant;
use crate::data::dataset::{BlockId, DataBlock, EdgePopulation, UserId};
use crate::data::trace::UnlearnRequest;
use crate::fleet::FleetService;
use crate::prng::Rng;
use crate::sim::Battery;
use crate::unlearning::UnlearningService;
use crate::util::Json;

pub use chaos::{run_chaos, ChaosCfg, ChaosPlan, ChaosReport, FaultClass};
pub use hist::LatencyHistogram;
pub use scenarios::corpus;

// ---------------------------------------------------------------------
// Request factory: sample-conserving deletion-request generation
// ---------------------------------------------------------------------

/// Draws deletion requests from a population while conserving samples:
/// a block can never have more samples unlearned than it holds, matching
/// the clamping in `RequestTrace::generate`. Scenarios use the query
/// helpers for skewed selection (a user's live blocks, the globally
/// oldest live block) and [`RequestFactory::take`] to consume.
pub struct RequestFactory<'a> {
    pop: &'a EdgePopulation,
    remaining: BTreeMap<BlockId, u64>,
    ingested: u32,
}

impl<'a> RequestFactory<'a> {
    pub fn new(pop: &'a EdgePopulation) -> Self {
        RequestFactory { pop, remaining: BTreeMap::new(), ingested: 0 }
    }

    /// Make the next training round's blocks available for deletion
    /// requests. Returns `false` once every round is ingested.
    pub fn ingest_round(&mut self) -> bool {
        if self.ingested >= self.pop.rounds() {
            return false;
        }
        self.ingested += 1;
        for b in self.pop.blocks_at(self.ingested) {
            self.remaining.insert(b.id, b.samples);
        }
        true
    }

    pub fn ingested_rounds(&self) -> u32 {
        self.ingested
    }

    pub fn population(&self) -> &EdgePopulation {
        self.pop
    }

    /// Samples still deletable in a block (0 if unknown or depleted).
    pub fn remaining_of(&self, id: BlockId) -> u64 {
        self.remaining.get(&id).copied().unwrap_or(0)
    }

    /// A user's ingested blocks that still hold deletable samples.
    pub fn live_user_blocks(&self, user: UserId) -> Vec<(BlockId, u64)> {
        self.pop
            .user_blocks(user, self.ingested)
            .into_iter()
            .filter_map(|b| {
                let left = self.remaining_of(b.id);
                (left > 0).then_some((b.id, left))
            })
            .collect()
    }

    /// Total deletable samples a user still owns.
    pub fn user_remaining(&self, user: UserId) -> u64 {
        self.live_user_blocks(user).iter().map(|(_, n)| n).sum()
    }

    /// The oldest (earliest-round, then first-listed) block that still
    /// holds deletable samples — the adversarial replay-maximizing
    /// target, since deleting from it invalidates the longest suffix.
    pub fn oldest_live_block(&self) -> Option<&DataBlock> {
        (1..=self.ingested)
            .flat_map(|r| self.pop.blocks_at(r))
            .find(|b| self.remaining_of(b.id) > 0)
    }

    /// Consume `frac` of a block's *remaining* samples (at least 1,
    /// clamped to what's left). `None` if the block is depleted.
    pub fn take(&mut self, id: BlockId, frac: f64) -> Option<(BlockId, u64)> {
        let left = self.remaining.get_mut(&id).filter(|l| **l > 0)?;
        let n = ((*left as f64 * frac).round() as u64).clamp(1, *left);
        *left -= n;
        Some((id, n))
    }
}

// ---------------------------------------------------------------------
// Arrival schedule: open-loop, fractional, intensity-modulated
// ---------------------------------------------------------------------

/// Fractional-rate arrival accumulator. `due(rate, intensity)` returns
/// how many requests arrive this tick; sub-unit rates accumulate so the
/// long-run arrival count equals `sum(rate * intensity)` exactly (±1),
/// independent of how fast the service drains — that's what makes the
/// harness open-loop.
#[derive(Clone, Debug, Default)]
pub struct ArrivalSchedule {
    carry: f64,
}

impl ArrivalSchedule {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn due(&mut self, offered_per_tick: f64, intensity: f64) -> u64 {
        self.carry += (offered_per_tick * intensity).max(0.0);
        let n = self.carry.floor();
        self.carry -= n;
        n as u64
    }
}

// ---------------------------------------------------------------------
// Scenario trait
// ---------------------------------------------------------------------

/// One workload in the corpus. Implementations must be deterministic
/// functions of the tick and the passed-in [`Rng`] — no interior state
/// that survives across runs — so the same seed replays byte-identically
/// (asserted for every corpus member in `tests/load_scenarios.rs`).
pub trait Scenario {
    /// Stable identifier — also the `load.<name>_rps_at_slo` gate key.
    fn name(&self) -> &'static str;

    fn description(&self) -> &'static str;

    /// Experiment shape (population size, shards, batching policy,
    /// model). `fleet_workers > 1` makes the harness drive a
    /// [`FleetService`] with per-shard latency histograms.
    fn config(&self) -> ExperimentConfig;

    /// Battery attached to the service (per worker in fleet mode).
    /// Scenarios carry one so the energy envelope — not CPU — is the
    /// saturating resource, as on the paper's devices.
    fn battery(&self) -> Option<Battery>;

    /// Harvest seconds landed after each tick (contact windows and
    /// day/night cycles express themselves here).
    fn harvest_secs(&self, tick: u64) -> f64;

    /// Arrival-rate multiplier at a tick (diurnal shapes, bursts).
    fn intensity(&self, _tick: u64) -> f64 {
        1.0
    }

    /// Queueing-delay SLO in ticks: a run meets SLO iff every submitted
    /// request is served, nothing stays parked, and p99 queueing delay
    /// is within this bound.
    fn slo_ticks(&self) -> u64;

    /// Draw one deletion request. `None` means the scenario ran out of
    /// deletable data (reported, and the run keeps going).
    fn make_request(
        &self,
        factory: &mut RequestFactory,
        rng: &mut Rng,
    ) -> Option<UnlearnRequest>;

    /// Per-tick hook into the service (fleet churn uses it to resize
    /// the active shard set).
    fn on_tick(&self, _tick: u64, _svc: &mut ServiceUnderTest) {}

    /// Scenario knobs, echoed into `BENCH_load.json` for readers.
    fn knobs(&self) -> Json {
        Json::obj()
    }

    /// Population the scenario runs against; the default mirrors
    /// `experiments::common::population`. Override to skew block sizes.
    fn population(&self, cfg: &ExperimentConfig) -> EdgePopulation {
        crate::experiments::common::population(cfg)
    }
}

// ---------------------------------------------------------------------
// Service-under-test: one façade over single-node and fleet services
// ---------------------------------------------------------------------

/// The harness drives either service through one surface so scenarios
/// don't care about the deployment shape. Fleet accessors are `Result`
/// (they cross worker channels); the single-node arm wraps infallibly.
pub enum ServiceUnderTest {
    Single(Box<UnlearningService>),
    Fleet(FleetService),
}

impl ServiceUnderTest {
    /// Build from a scenario's config: `fleet_workers > 1` routes
    /// through the sharded fleet, otherwise the single-node service.
    pub fn build(cfg: &ExperimentConfig, battery: Option<Battery>) -> Result<Self> {
        if cfg.fleet_workers > 1 {
            let mut fleet = SystemVariant::Cause.build_fleet(cfg)?;
            if let Some(b) = battery {
                fleet = fleet.with_battery(b);
            }
            Ok(ServiceUnderTest::Fleet(fleet))
        } else {
            let mut svc = SystemVariant::Cause.build_service(cfg)?;
            if let Some(b) = battery {
                svc = svc.with_battery(b);
            }
            Ok(ServiceUnderTest::Single(Box::new(svc)))
        }
    }

    pub fn submit(&mut self, req: UnlearnRequest) {
        match self {
            ServiceUnderTest::Single(s) => s.submit(req),
            ServiceUnderTest::Fleet(f) => f.submit(req),
        }
    }

    pub fn ingest_round(&mut self, pop: &EdgePopulation) -> Result<()> {
        match self {
            ServiceUnderTest::Single(s) => s.ingest_round(pop),
            ServiceUnderTest::Fleet(f) => f.ingest_round(pop),
        }
    }

    pub fn advance(&mut self, ticks: u64) {
        match self {
            ServiceUnderTest::Single(s) => s.advance(ticks),
            ServiceUnderTest::Fleet(f) => f.advance(ticks),
        }
    }

    pub fn harvest(&mut self, secs: f64) {
        match self {
            ServiceUnderTest::Single(s) => s.harvest(secs),
            ServiceUnderTest::Fleet(f) => f.harvest(secs),
        }
    }

    pub fn drain_batched(&mut self) -> Result<usize> {
        match self {
            ServiceUnderTest::Single(s) => s.drain_batched(),
            ServiceUnderTest::Fleet(f) => f.drain_batched(),
        }
    }

    pub fn flush_batched(&mut self) -> Result<usize> {
        match self {
            ServiceUnderTest::Single(s) => s.flush_batched(),
            ServiceUnderTest::Fleet(f) => f.flush_batched(),
        }
    }

    pub fn pending(&self) -> Result<usize> {
        match self {
            ServiceUnderTest::Single(s) => Ok(s.pending()),
            ServiceUnderTest::Fleet(f) => f.pending(),
        }
    }

    pub fn carryover_requests(&self) -> Result<usize> {
        match self {
            ServiceUnderTest::Single(s) => Ok(s.carryover_requests()),
            ServiceUnderTest::Fleet(f) => f.carryover_requests(),
        }
    }

    pub fn carryover_lineages(&self) -> Result<usize> {
        match self {
            ServiceUnderTest::Single(s) => Ok(s.carryover_lineages()),
            ServiceUnderTest::Fleet(f) => f.carryover_lineages(),
        }
    }

    /// Resize the fleet's active shard set; no-op on the single service.
    pub fn set_active_shards(&mut self, n: usize) {
        if let ServiceUnderTest::Fleet(f) = self {
            f.set_active_shards(n);
        }
    }

    /// Stamp an instant marker into the trace (front-end lane in fleet
    /// mode). No-op when tracing is off.
    pub fn obs_marker(&mut self, name: &'static str) {
        match self {
            ServiceUnderTest::Single(s) => s.obs_marker(name),
            ServiceUnderTest::Fleet(f) => f.obs_marker(name),
        }
    }

    /// Every retained span record (front-end lane first in fleet mode).
    pub fn trace_records(&self) -> Result<Vec<crate::obs::SpanRec>> {
        match self {
            ServiceUnderTest::Single(s) => Ok(s.obs_records()),
            ServiceUnderTest::Fleet(f) => f.trace_records(),
        }
    }

    /// The service's named-metrics registry (shard-merged in fleet mode;
    /// verbatim for one worker).
    pub fn registry(&self) -> Result<crate::obs::Registry> {
        match self {
            ServiceUnderTest::Single(s) => Ok(s.registry()),
            ServiceUnderTest::Fleet(f) => f.registry(),
        }
    }

    /// Per-shard latency histograms (one for the single service), plus
    /// served-receipt count, SLO violations against `slo_ticks`, and
    /// total retrain energy. The fleet arm takes the histograms straight
    /// off the front-end ([`FleetService::shard_latency_histograms`] —
    /// recorded at the workers, violations counted exactly against the
    /// raw delays there) instead of rebuilding them from raw metrics
    /// here. Per-shard recording + lossless merge is the property `hist`
    /// pins down.
    pub fn latency_report(&mut self, slo_ticks: u64) -> Result<LatencyReportRaw> {
        match self {
            ServiceUnderTest::Single(s) => {
                // The incremental histogram covers receipts folded out of
                // the capped vec; the exact violation count still scans
                // the retained receipts.
                let h = s.engine().metrics.latency_hist.clone();
                let mut violations = 0u64;
                for r in &s.engine().metrics.latency {
                    if r.queued_ticks > slo_ticks {
                        violations += 1;
                    }
                }
                Ok(LatencyReportRaw {
                    served: h.count(),
                    shard_hists: vec![h],
                    violations,
                    energy_joules: s.engine().metrics.energy_joules,
                })
            }
            ServiceUnderTest::Fleet(f) => {
                let per_shard = f.shard_latency_histograms(slo_ticks)?;
                let energy_joules = f.metrics()?.energy_joules;
                let mut shard_hists = Vec::with_capacity(per_shard.len());
                let mut served = 0u64;
                let mut violations = 0u64;
                for (h, v) in per_shard {
                    served += h.count();
                    violations += v;
                    shard_hists.push(h);
                }
                Ok(LatencyReportRaw { shard_hists, served, violations, energy_joules })
            }
        }
    }
}

/// Raw latency data off the service: per-shard histograms + counters.
pub struct LatencyReportRaw {
    pub shard_hists: Vec<LatencyHistogram>,
    pub served: u64,
    pub violations: u64,
    pub energy_joules: f64,
}

// ---------------------------------------------------------------------
// Open-loop run
// ---------------------------------------------------------------------

/// Shape of one open-loop run (everything but the scenario).
#[derive(Clone, Copy, Debug)]
pub struct OpenLoopCfg {
    /// Offered arrival rate, requests per tick (before intensity).
    pub offered_per_tick: f64,
    /// Ticks of open-loop arrivals.
    pub ticks: u64,
    /// Max extra ticks (with harvest) to let the service finish queued
    /// and battery-parked work after arrivals stop. A scenario that
    /// can't finish within the tail is saturated: `slo_ok = false`.
    pub tail_ticks: u64,
    /// Seed for the scenario's request-selection RNG.
    pub seed: u64,
    /// Enable span tracing on the service under test: scenario phases are
    /// stamped as trace markers and the report carries a Chrome-trace
    /// export. Receipts and metrics are unaffected either way.
    pub obs: bool,
}

impl Default for OpenLoopCfg {
    fn default() -> Self {
        OpenLoopCfg {
            offered_per_tick: 1.0,
            ticks: 64,
            tail_ticks: 256,
            seed: 0x10ad,
            obs: false,
        }
    }
}

/// Everything one open-loop run produced. `to_json` is deterministic
/// (logical ticks only — no wall clock), which is what lets the
/// determinism tests byte-compare reports and `bench_gate` ratchet
/// `rps_at_slo` floors like any other deterministic counter.
pub struct LoadReport {
    pub scenario: String,
    pub offered_per_tick: f64,
    pub ticks: u64,
    pub tail_used: u64,
    pub submitted: u64,
    pub served: u64,
    pub unserved: u64,
    pub exhausted: bool,
    pub slo_ticks: u64,
    pub violations: u64,
    pub energy_joules: f64,
    pub slo_ok: bool,
    pub trace_digest: u64,
    pub hist: LatencyHistogram,
    /// Cross-layer telemetry pulled from the service registry (shipping
    /// retries, journal fsync stats, latency-cap counters) — flat, so
    /// harness binaries print it without digging through receipt JSON.
    pub telemetry: Json,
    /// Chrome-trace export of the run's spans when `OpenLoopCfg::obs`
    /// was set (`None` otherwise). Deliberately NOT part of `to_json`:
    /// reports stay byte-comparable and small; callers that want the
    /// trace write it separately.
    pub trace: Option<Json>,
}

impl LoadReport {
    pub fn p50(&self) -> u64 {
        self.hist.quantile(0.50)
    }

    pub fn p99(&self) -> u64 {
        self.hist.quantile(0.99)
    }

    pub fn p999(&self) -> u64 {
        self.hist.quantile(0.999)
    }

    /// Histogram-sanity tail ratio, +1-shifted so an all-zero-delay run
    /// (p50 = 0) still yields a finite, comparable number.
    pub fn p999_over_p50(&self) -> f64 {
        (self.p999() + 1) as f64 / (self.p50() + 1) as f64
    }

    pub fn to_json(&self) -> Json {
        Json::obj()
            .set("scenario", self.scenario.as_str())
            .set("offered_per_tick", self.offered_per_tick)
            .set("ticks", self.ticks)
            .set("tail_used", self.tail_used)
            .set("submitted", self.submitted)
            .set("served", self.served)
            .set("unserved", self.unserved)
            .set("exhausted", self.exhausted)
            .set("slo_ticks", self.slo_ticks)
            .set("violations", self.violations)
            .set("energy_joules", self.energy_joules)
            .set("slo_ok", self.slo_ok)
            .set("trace_digest", format!("{:016x}", self.trace_digest))
            .set("p999_over_p50", self.p999_over_p50())
            .set("hist", self.hist.to_json())
            .set("telemetry", self.telemetry.clone())
    }
}

/// FNV-1a, folding a byte slice into a running digest.
fn fnv_fold(mut h: u64, bytes: &[u8]) -> u64 {
    for &b in bytes {
        h ^= b as u64;
        h = h.wrapping_mul(0x100_0000_01b3);
    }
    h
}

fn fold_request(mut h: u64, req: &UnlearnRequest) -> u64 {
    h = fnv_fold(h, &req.round.to_le_bytes());
    h = fnv_fold(h, &req.user.0.to_le_bytes());
    for (id, n) in &req.parts {
        h = fnv_fold(h, &id.0.to_le_bytes());
        h = fnv_fold(h, &n.to_le_bytes());
    }
    h
}

const FNV_OFFSET: u64 = 0xcbf2_9ce4_8422_2325;

/// Run one scenario open-loop at one offered rate.
///
/// Phases: (1) preload — every training round is ingested so the full
/// lineage structure exists before load starts; (2) arrival — each tick
/// the schedule emits `floor(rate * intensity + carry)` requests which
/// are submitted regardless of service progress, then the clock ticks,
/// harvest lands, the scenario's hook runs, and one batched drain
/// executes whatever window closed; (3) tail — up to `tail_ticks` of
/// harvest + flush to let queued and battery-parked work finish.
pub fn run_open_loop(scenario: &dyn Scenario, run: &OpenLoopCfg) -> Result<LoadReport> {
    let mut cfg = scenario.config();
    if run.obs {
        cfg.obs = true;
    }
    let pop = scenario.population(&cfg);
    let mut sut = ServiceUnderTest::build(&cfg, scenario.battery())?;
    let mut factory = RequestFactory::new(&pop);

    // Phase 1: preload all training rounds.
    for _ in 0..pop.rounds() {
        sut.ingest_round(&pop)?;
        factory.ingest_round();
    }
    sut.obs_marker("phase:arrivals");

    // Separate the request-selection stream per scenario so corpus
    // members never share random decisions even under one seed.
    let mut rng = Rng::new(fnv_fold(run.seed ^ FNV_OFFSET, scenario.name().as_bytes()));
    let mut schedule = ArrivalSchedule::new();
    let mut digest = FNV_OFFSET;
    let mut submitted = 0u64;
    let mut exhausted = false;

    // Phase 2: open-loop arrivals.
    for t in 0..run.ticks {
        for _ in 0..schedule.due(run.offered_per_tick, scenario.intensity(t)) {
            match scenario.make_request(&mut factory, &mut rng) {
                Some(req) => {
                    digest = fold_request(digest, &req);
                    sut.submit(req);
                    submitted += 1;
                }
                None => exhausted = true,
            }
        }
        sut.advance(1);
        let h = scenario.harvest_secs(t);
        if h > 0.0 {
            sut.harvest(h);
        }
        scenario.on_tick(t, &mut sut);
        sut.drain_batched()?;
    }

    // Phase 3: bounded drain tail.
    sut.obs_marker("phase:tail");
    let mut tail_used = 0u64;
    while tail_used < run.tail_ticks {
        if sut.pending()? == 0
            && sut.carryover_requests()? == 0
            && sut.carryover_lineages()? == 0
        {
            break;
        }
        sut.advance(1);
        let h = scenario.harvest_secs(run.ticks + tail_used);
        if h > 0.0 {
            sut.harvest(h);
        }
        sut.flush_batched()?;
        tail_used += 1;
    }

    let slo_ticks = scenario.slo_ticks();
    let raw = sut.latency_report(slo_ticks)?;
    let mut hist = LatencyHistogram::new();
    for h in &raw.shard_hists {
        hist.merge(h);
    }
    let unserved = submitted.saturating_sub(raw.served);
    let leftover_lineages = sut.carryover_lineages()?;
    let slo_ok =
        unserved == 0 && leftover_lineages == 0 && hist.quantile(0.99) <= slo_ticks;

    let reg = sut.registry()?;
    let telemetry = Json::obj()
        .set("ship_attempts", reg.counter("ship.attempts"))
        .set("ship_faults", reg.counter("ship.faults"))
        .set("ship_failed", reg.counter("ship.failed"))
        .set("journal_appended", reg.counter("journal.appended"))
        .set("journal_fsyncs", reg.counter("journal.fsyncs"))
        .set("latency_dropped", reg.counter("latency.dropped"))
        .set("latency_slo_miss", reg.counter("latency.slo_miss"));
    let trace = if cfg.obs {
        Some(crate::obs::export::chrome_trace(&sut.trace_records()?))
    } else {
        None
    };

    Ok(LoadReport {
        scenario: scenario.name().to_string(),
        offered_per_tick: run.offered_per_tick,
        ticks: run.ticks,
        tail_used,
        submitted,
        served: raw.served,
        unserved,
        exhausted,
        slo_ticks,
        violations: raw.violations,
        energy_joules: raw.energy_joules,
        slo_ok,
        trace_digest: digest,
        hist,
        telemetry,
        trace,
    })
}

/// Sweep offered rates (ascending) and report the highest rate at which
/// the scenario still met its SLO, plus every per-rate report.
pub fn sweep(
    scenario: &dyn Scenario,
    rates: &[f64],
    base: &OpenLoopCfg,
) -> Result<(f64, Vec<LoadReport>)> {
    let mut rps_at_slo = 0.0f64;
    let mut reports = Vec::with_capacity(rates.len());
    for &rate in rates {
        let report =
            run_open_loop(scenario, &OpenLoopCfg { offered_per_tick: rate, ..*base })?;
        if report.slo_ok {
            rps_at_slo = rps_at_slo.max(rate);
        }
        reports.push(report);
    }
    Ok((rps_at_slo, reports))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::catalog::CIFAR10;
    use crate::data::dataset::PopulationConfig;
    use crate::testkit::forall;

    #[test]
    fn arrival_schedule_accumulates_fractional_rates() {
        let mut s = ArrivalSchedule::new();
        let half: Vec<u64> = (0..6).map(|_| s.due(0.5, 1.0)).collect();
        assert_eq!(half, vec![0, 1, 0, 1, 0, 1]);
        let mut s = ArrivalSchedule::new();
        let mixed: Vec<u64> = (0..4).map(|_| s.due(2.5, 1.0)).collect();
        assert_eq!(mixed, vec![2, 3, 2, 3]);
        // Long-run conservation under varying intensity.
        let mut s = ArrivalSchedule::new();
        let mut total = 0u64;
        let mut offered = 0.0;
        for t in 0..1000u64 {
            let intensity = 1.0 + 0.9 * ((t as f64) * 0.1).sin();
            offered += 0.7 * intensity;
            total += s.due(0.7, intensity);
        }
        assert!((total as f64 - offered).abs() <= 1.0, "{total} vs {offered}");
    }

    #[test]
    fn prop_factory_conserves_samples() {
        let pop = EdgePopulation::generate(PopulationConfig {
            spec: CIFAR10.scaled(4_000),
            users: 12,
            rounds: 4,
            size_sigma: 0.8,
            label_alpha: 0.5,
            arrival_prob: 0.8,
            seed: 4242,
        });
        forall(
            0x10ad3,
            60,
            |rng, size| {
                let takes = 1 + (60.0 * size) as usize;
                (0..takes).map(|_| (rng.below(1_000_000), rng.f64())).collect::<Vec<_>>()
            },
            |takes| {
                let mut f = RequestFactory::new(&pop);
                while f.ingest_round() {}
                let all_blocks: Vec<BlockId> = (1..=pop.rounds())
                    .flat_map(|r| pop.blocks_at(r).iter().map(|b| b.id))
                    .collect();
                let mut consumed: BTreeMap<BlockId, u64> = BTreeMap::new();
                for &(pick, frac) in takes {
                    let id = all_blocks[(pick % all_blocks.len() as u64) as usize];
                    let before = f.remaining_of(id);
                    match f.take(id, frac) {
                        Some((tid, n)) => {
                            if tid != id || n == 0 || n > before {
                                return Err(format!(
                                    "take({id:?}) returned {n} with {before} left"
                                ));
                            }
                            *consumed.entry(id).or_insert(0) += n;
                        }
                        None if before != 0 => {
                            return Err(format!("take refused live block {id:?}"));
                        }
                        None => {}
                    }
                }
                for id in &all_blocks {
                    let cap = pop.block(*id).unwrap().samples;
                    let used = consumed.get(id).copied().unwrap_or(0);
                    if used + f.remaining_of(*id) != cap {
                        return Err(format!("block {id:?} leaked samples"));
                    }
                }
                Ok(())
            },
        );
    }

    #[test]
    fn factory_oldest_live_block_walks_rounds_in_order() {
        let pop = EdgePopulation::generate(PopulationConfig {
            spec: CIFAR10.scaled(2_000),
            users: 6,
            rounds: 3,
            size_sigma: 0.5,
            label_alpha: 0.5,
            arrival_prob: 1.0,
            seed: 7,
        });
        let mut f = RequestFactory::new(&pop);
        while f.ingest_round() {}
        // Deplete round 1 entirely; the oldest live block must move to
        // round 2's first block.
        for b in pop.blocks_at(1) {
            assert!(f.take(b.id, 1.0).is_some());
        }
        let oldest = f.oldest_live_block().expect("rounds 2..3 still live");
        assert_eq!(oldest.round, 2);
        assert_eq!(oldest.id, pop.blocks_at(2)[0].id);
    }
}
