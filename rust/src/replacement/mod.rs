//! Sub-model replacement policies for the checkpoint store.
//!
//! When the device memory is full, a policy picks the slot whose checkpoint
//! the newly trained sub-model overwrites. The paper contributes FiboR
//! (Fibonacci-stride victim selection, Algorithm 2) and compares it against
//! no-replacement (what SISA/ARCANE/OMP effectively do), FIFO, and random.

pub mod fibor;
pub mod fifo;
pub mod random_policy;
pub mod static_policy;

pub use fibor::FiboR;
pub use fifo::Fifo;
pub use random_policy::RandomReplace;
pub use static_policy::NoReplace;

/// A victim-selection policy over `capacity` memory slots.
///
/// The store calls `victim` only when memory is full; a `None` means
/// "drop the new checkpoint instead of evicting" (the no-replacement
/// baselines). Policies are deliberately *stateless about contents* —
/// exactly like the paper's Algorithm 2, which walks slot indices.
///
/// `Sync` is required so the batch executor can resolve retrain chains
/// against a shared `&ModelStore` from scoped threads (reads only; all
/// mutation stays on the engine thread).
pub trait ReplacementPolicy: Send + Sync {
    fn name(&self) -> &'static str;

    /// Slot to evict for the next incoming checkpoint, or `None` to reject.
    fn victim(&mut self, capacity: usize) -> Option<usize>;

    /// Whether a full store would evict (`true`) or reject (`false`) on
    /// the next store attempt. Must agree with [`ReplacementPolicy::victim`]
    /// returning `Some`/`None`, but must not advance policy state — it is
    /// the read-only admission probe behind
    /// [`ModelStore::would_accept`](crate::memory::ModelStore::would_accept).
    fn would_evict(&self) -> bool {
        true
    }

    /// Reset internal counters (new run).
    fn reset(&mut self);

    /// Internal counters as raw words, for durability snapshots. Stateless
    /// policies return an empty vec. Paired with
    /// [`ReplacementPolicy::restore_state`]: after a crash, restoring the
    /// saved words must make the victim stream continue exactly where the
    /// pre-crash run left off.
    fn persist_state(&self) -> Vec<u64> {
        Vec::new()
    }

    /// Restore counters saved by [`ReplacementPolicy::persist_state`].
    /// Must accept the empty vec (fresh state) and its own output.
    fn restore_state(&mut self, _state: &[u64]) {}
}

/// Construct a policy by name (CLI / config use).
pub fn by_name(name: &str, seed: u64) -> Option<Box<dyn ReplacementPolicy>> {
    match name {
        "fibor" => Some(Box::new(FiboR::new())),
        "fifo" => Some(Box::new(Fifo::new())),
        "random" => Some(Box::new(RandomReplace::new(seed))),
        "none" | "static" => Some(Box::new(NoReplace)),
        _ => None,
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn by_name_resolves_all() {
        for n in ["fibor", "fifo", "random", "none"] {
            assert!(by_name(n, 1).is_some(), "{n}");
        }
        assert!(by_name("lru", 1).is_none());
    }

    /// Saving mid-stream and restoring into a fresh policy must continue
    /// the exact victim sequence — the property crash recovery relies on.
    #[test]
    fn persist_state_continues_victim_stream() {
        for n in ["fibor", "fifo", "random", "none"] {
            let mut live = by_name(n, 9).unwrap();
            for _ in 0..13 {
                let _ = live.victim(7);
            }
            let saved = live.persist_state();
            let mut recovered = by_name(n, 9).unwrap();
            recovered.restore_state(&saved);
            for step in 0..50 {
                assert_eq!(
                    live.victim(7),
                    recovered.victim(7),
                    "{n} diverged at step {step}"
                );
            }
            // Restoring the empty vec (fresh state) is a no-op.
            recovered.restore_state(&[]);
        }
    }

    #[test]
    fn victims_always_in_range() {
        for n in ["fibor", "fifo", "random"] {
            let mut p = by_name(n, 2).unwrap();
            assert!(p.would_evict(), "{n} is an evicting policy");
            for _ in 0..200 {
                let v = p.victim(7).unwrap();
                assert!(v < 7, "{n} produced victim {v}");
            }
        }
    }
}
