//! FIFO replacement — the classic baseline the paper contrasts in Fig. 7.
//!
//! Victims cycle 0, 1, 2, …, N−1, 0, …: memory always holds the N newest
//! sub-models. Good for unlearning *recent* data, catastrophic for old data
//! (the original checkpoint is long gone → retrain from scratch).

use crate::replacement::ReplacementPolicy;

pub struct Fifo {
    next: usize,
}

impl Fifo {
    pub fn new() -> Self {
        Self { next: 0 }
    }
}

impl Default for Fifo {
    fn default() -> Self {
        Self::new()
    }
}

impl ReplacementPolicy for Fifo {
    fn name(&self) -> &'static str {
        "fifo"
    }

    fn victim(&mut self, capacity: usize) -> Option<usize> {
        assert!(capacity > 0);
        let v = self.next % capacity;
        self.next = (v + 1) % capacity;
        Some(v)
    }

    fn reset(&mut self) {
        self.next = 0;
    }

    fn persist_state(&self) -> Vec<u64> {
        vec![self.next as u64]
    }

    fn restore_state(&mut self, state: &[u64]) {
        if let [next] = *state {
            self.next = next as usize;
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn cycles_in_order() {
        let mut f = Fifo::new();
        let vs: Vec<usize> = (0..7).map(|_| f.victim(3).unwrap()).collect();
        assert_eq!(vs, vec![0, 1, 2, 0, 1, 2, 0]);
    }

    #[test]
    fn capacity_shrink_stays_in_range() {
        let mut f = Fifo::new();
        for _ in 0..5 {
            f.victim(8);
        }
        assert!(f.victim(3).unwrap() < 3);
    }
}
