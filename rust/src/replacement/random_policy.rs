//! Random replacement — the "jump" strategy the paper's §4.4 remark
//! compares FiboR against (unstable temporal sparsity).

use crate::prng::Rng;
use crate::replacement::ReplacementPolicy;

pub struct RandomReplace {
    rng: Rng,
    seed: u64,
}

impl RandomReplace {
    pub fn new(seed: u64) -> Self {
        Self { rng: Rng::new(seed), seed }
    }
}

impl ReplacementPolicy for RandomReplace {
    fn name(&self) -> &'static str {
        "random"
    }

    fn victim(&mut self, capacity: usize) -> Option<usize> {
        assert!(capacity > 0);
        Some(self.rng.below(capacity as u64) as usize)
    }

    fn reset(&mut self) {
        self.rng = Rng::new(self.seed);
    }

    fn persist_state(&self) -> Vec<u64> {
        let s = self.rng.state();
        vec![s[0], s[1], s[2], s[3], self.seed]
    }

    fn restore_state(&mut self, state: &[u64]) {
        if let [a, b, c, d, seed] = *state {
            self.rng = Rng::from_state([a, b, c, d]);
            self.seed = seed;
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn covers_all_slots_eventually() {
        let mut p = RandomReplace::new(1);
        let mut seen = [false; 6];
        for _ in 0..300 {
            seen[p.victim(6).unwrap()] = true;
        }
        assert!(seen.iter().all(|s| *s));
    }

    #[test]
    fn reset_reproduces_stream() {
        let mut p = RandomReplace::new(2);
        let a: Vec<usize> = (0..10).map(|_| p.victim(5).unwrap()).collect();
        p.reset();
        let b: Vec<usize> = (0..10).map(|_| p.victim(5).unwrap()).collect();
        assert_eq!(a, b);
    }
}
