//! FiboR — Fibonacci-based replacement (paper §4.4, Algorithm 2).
//!
//! The replacement index jumps by Fibonacci strides:
//!
//! ```text
//! I_replace = [ I_replace + f(I_FiboR) % N_mem ] % N_mem
//! ```
//!
//! where `f` is the *distinct-value* Fibonacci sequence 0, 1, 2, 3, 5, 8, 13…
//! (standard Fibonacci with the duplicate 1 removed, i.e. f(0) = 0 and
//! f(k) = F(k+1) for k ≥ 1). That is the only reading under which the
//! paper's worked example (Fig. 8) checks out: with capacity 8, M9..M14
//! replace slots 1, 2, 4, 7, then the slot holding M11, then the slot
//! holding M13, leaving {M3, M5, M6, M8, M9, M10, M12, M14} in memory —
//! reproduced in `paper_example` below.
//!
//! The cyclic, non-uniform visit pattern gives *temporal sparsity*: some
//! slots are revisited rarely and keep old checkpoints alive (the paper's
//! capacity-10 remark: a 60-step period in which some slots are replaced
//! only 4 times vs the uniform 6), so for an arbitrary unlearning request
//! a checkpoint near the retrain start point usually survives.
//!
//! Fibonacci values are maintained *mod N_mem* incrementally, so the state
//! never overflows no matter how long the device runs.

use crate::replacement::ReplacementPolicy;

/// FiboR policy state.
pub struct FiboR {
    /// Current replacement index (0-based; the paper is 1-based).
    i_replace: usize,
    /// Next position k in the distinct-Fibonacci sequence (I_FiboR).
    k: u64,
    /// F(k) mod m and F(k+1) mod m for the current k (valid when k >= 1).
    fa: u64,
    fb: u64,
    /// Modulus the (fa, fb) state is valid for; 0 = not initialized.
    m: usize,
}

impl FiboR {
    pub fn new() -> Self {
        Self { i_replace: 0, k: 0, fa: 0, fb: 0, m: 0 }
    }

    /// Recompute (F(k) mod cap, F(k+1) mod cap) from scratch — only needed
    /// when the store capacity changes mid-run (rare).
    fn rebuild(&mut self, cap: usize) {
        let (mut a, mut b) = (0u64, 1u64); // F(0), F(1)
        for _ in 0..self.k {
            let c = (a + b) % cap as u64;
            a = b;
            b = c;
        }
        self.fa = a;
        self.fb = b;
        self.m = cap;
    }
}

impl Default for FiboR {
    fn default() -> Self {
        Self::new()
    }
}

impl ReplacementPolicy for FiboR {
    fn name(&self) -> &'static str {
        "fibor"
    }

    fn victim(&mut self, capacity: usize) -> Option<usize> {
        assert!(capacity > 0);
        let cap64 = capacity as u64;
        // Stride f(k) mod capacity.
        let stride = if self.k == 0 {
            0
        } else {
            if self.m != capacity {
                self.rebuild(capacity);
            }
            (self.fb % cap64) as usize // f(k) = F(k+1)
        };
        // Advance to k+1, keeping (fa, fb) = (F(k), F(k+1)) mod capacity.
        self.k += 1;
        if self.k == 1 || self.m != capacity {
            self.rebuild(capacity);
        } else {
            let c = (self.fa + self.fb) % cap64;
            self.fa = self.fb;
            self.fb = c;
        }
        self.i_replace = (self.i_replace + stride) % capacity;
        Some(self.i_replace)
    }

    fn reset(&mut self) {
        *self = FiboR::new();
    }

    fn persist_state(&self) -> Vec<u64> {
        vec![self.i_replace as u64, self.k, self.fa, self.fb, self.m as u64]
    }

    fn restore_state(&mut self, state: &[u64]) {
        if let [i_replace, k, fa, fb, m] = *state {
            self.i_replace = i_replace as usize;
            self.k = k;
            self.fa = fa;
            self.fb = fb;
            self.m = m as usize;
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    /// The paper's Fig. 8 example: capacity 8, models M1..M8 fill memory,
    /// then M9..M14 replace M1, M2, M4, M7, M11, M13 leaving
    /// {M3, M5, M6, M8, M9, M10, M12, M14}.
    #[test]
    fn paper_example() {
        let mut slots: Vec<u32> = (1..=8).collect(); // slot i holds M(i+1)
        let mut fibor = FiboR::new();
        for m in 9..=14u32 {
            let v = fibor.victim(8).unwrap();
            slots[v] = m;
        }
        let mut stored = slots.clone();
        stored.sort_unstable();
        assert_eq!(stored, vec![3, 5, 6, 8, 9, 10, 12, 14]);
    }

    /// Replacement order of the example, slot by slot (0-based).
    #[test]
    fn paper_example_victim_order() {
        let mut fibor = FiboR::new();
        let victims: Vec<usize> = (0..6).map(|_| fibor.victim(8).unwrap()).collect();
        // M9->slot0 (M1), M10->slot1 (M2), M11->slot3 (M4), M12->slot6 (M7),
        // M13->slot3 (M11), M14->slot3 (M13).
        assert_eq!(victims, vec![0, 1, 3, 6, 3, 3]);
    }

    /// The paper's capacity-10 remark: the pattern repeats every 60
    /// replacements (Pisano period of 10), and some slots are visited
    /// less often than the uniform 6 (temporal sparsity).
    #[test]
    fn capacity_10_cycle_and_sparsity() {
        let mut fibor = FiboR::new();
        // Skip the k=0 zero-stride step so the cycle comparison starts in
        // the periodic regime.
        let _ = fibor.victim(10);
        let first: Vec<usize> = (0..60).map(|_| fibor.victim(10).unwrap()).collect();
        let second: Vec<usize> = (0..60).map(|_| fibor.victim(10).unwrap()).collect();
        assert_eq!(first, second, "pattern must repeat with period 60");
        let mut counts = [0usize; 10];
        for v in &first {
            counts[*v] += 1;
        }
        assert_eq!(counts.iter().sum::<usize>(), 60);
        let min = counts.iter().min().unwrap();
        assert!(*min < 6, "no temporally-sparse slot: {counts:?}");
        // Every slot is eventually replaced ("sufficient mix of new models").
        assert!(counts.iter().all(|c| *c > 0), "{counts:?}");
    }

    #[test]
    fn strides_match_distinct_fibonacci() {
        // f = 0, 1, 2, 3, 5, 8, 13, 21, ... mod capacity.
        let mut fibor = FiboR::new();
        let cap = 1000;
        let mut pos = 0usize;
        let expected = [0u64, 1, 2, 3, 5, 8, 13, 21, 34, 55, 89, 144, 233, 377, 610];
        for f in expected {
            let v = fibor.victim(cap).unwrap();
            pos = (pos + (f as usize % cap)) % cap;
            assert_eq!(v, pos);
        }
    }

    #[test]
    fn long_run_does_not_overflow_and_stays_in_range() {
        let mut fibor = FiboR::new();
        for _ in 0..100_000 {
            let v = fibor.victim(7).unwrap();
            assert!(v < 7);
        }
    }

    #[test]
    fn capacity_change_mid_run_is_consistent() {
        // Run k steps at cap 8, switch to cap 5: strides must still follow
        // f(k) mod 5 from the same global k.
        let mut fibor = FiboR::new();
        for _ in 0..4 {
            fibor.victim(8);
        }
        // k = 4 now; f(4) = F(5) = 5 -> stride 0 mod 5; position carries over
        // mod new capacity arithmetic.
        let before = fibor.i_replace;
        let v = fibor.victim(5).unwrap();
        assert_eq!(v, before % 5);
    }

    #[test]
    fn reset_restores_initial_sequence() {
        let mut fibor = FiboR::new();
        let a: Vec<usize> = (0..10).map(|_| fibor.victim(8).unwrap()).collect();
        fibor.reset();
        let b: Vec<usize> = (0..10).map(|_| fibor.victim(8).unwrap()).collect();
        assert_eq!(a, b);
    }
}
