//! No-replacement — what SISA/ARCANE/OMP do once memory fills (Fig. 6):
//! new sub-models are simply not stored.

use crate::replacement::ReplacementPolicy;

pub struct NoReplace;

impl ReplacementPolicy for NoReplace {
    fn name(&self) -> &'static str {
        "none"
    }

    fn victim(&mut self, _capacity: usize) -> Option<usize> {
        None
    }

    fn would_evict(&self) -> bool {
        false
    }

    fn reset(&mut self) {}
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn never_evicts() {
        let mut p = NoReplace;
        assert!(!p.would_evict());
        for cap in 1..10 {
            assert!(p.victim(cap).is_none());
        }
    }
}
