//! The exact-unlearning engine — Algorithm 3 of the paper, generalized so
//! CAUSE and every baseline are configuration points of the same loop.
//!
//! Per round t (Algorithm 3 lines 1–5):
//!   1. the shard controller yields S_t;
//!   2. the partitioner assigns the round's new blocks to shard lineages;
//!   3. every touched lineage trains incrementally on its new segment
//!      (with the system's pruning schedule interleaved — RCMP);
//!   4. the resulting sub-model checkpoint is stored per the replacement
//!      policy (FiboR for CAUSE; reject-when-full for SISA/ARCANE/OMP).
//!
//! Per unlearning request (lines 6–12):
//!   1. the affected lineages and their earliest poisoned segments are
//!      located through the block index;
//!   2. the unlearned samples are removed from the lineage bookkeeping;
//!   3. every stored checkpoint containing poisoned data is deleted
//!      (line 11);
//!   4. each affected lineage retrains from the newest surviving
//!      checkpoint that predates the poison (line 8) — or from scratch —
//!      and the retrained model is stored again via the policy (line 12);
//!   5. RSN += samples replayed — the paper's headline metric.
//!
//! ## Planner complexity
//!
//! The plan→price→execute hot path runs on incremental indices: pricing a
//! lineage's chain ([`Engine::plan_lineage_rsn`], the battery-admission
//! probe the service calls once per window per admission retry) costs
//! O(steps × log) — store lookups through the coverage index, replay
//! sizes through the lineage prefix sums — and allocates nothing. Replay
//! *sets* are materialized only when a plan actually executes.
//! [`Engine::resolve_plan_naive`] keeps the original scan-based resolution
//! alive as a differential oracle; the equivalence tests and `bench_scale`
//! assert both paths produce byte-identical receipts.

use std::collections::{BTreeMap, BTreeSet};
use std::sync::Arc;

use anyhow::Result;

use crate::config::ExperimentConfig;
use crate::coordinator::lineage::LineageSet;
use crate::data::dataset::{BlockId, EdgePopulation, UserId};
use crate::data::trace::{RequestTrace, UnlearnRequest};
use crate::energy::EnergyModel;
use crate::memory::{CapacityMode, Checkpoint, CheckpointId, ModelStore, StoreEvent, StoreStats};
use crate::metrics::RunMetrics;
use crate::partition::{Partitioner, Placement};
use crate::persist::event::{PlacementRecord, RoundRec, StoreEvRec, StoreOpRec};
use crate::persist::snapshot::{SlotCkpt, StoreImage};
use crate::pruning::PruneSchedule;
use crate::runtime::codec::{DecodeCache, EncodedParams, TensorCodec};
use crate::runtime::HostTensor;
use crate::shard_controller::ShardController;
use crate::training::{LineageWorker, TrainOutcome, Trainer};
use crate::unlearning::batch::{BatchPlan, LineagePlan};

/// When the engine measures ensemble accuracy (PJRT backend only).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum EvalPolicy {
    Never,
    FinalRound,
    EveryRound,
}

/// Outcome of one unlearning request (or one coalesced batch window).
#[derive(Clone, Debug, Default)]
pub struct UnlearnOutcome {
    pub rsn: u64,
    pub lineages_retrained: usize,
    pub warm_starts: usize,
    pub scratch_starts: usize,
    pub ckpts_invalidated: usize,
    /// Every `(lineage, covered_segments)` sub-model version this request
    /// or batch invalidated (Alg. 3 line 11) — the exact-unlearning audit
    /// trail the equivalence tests compare across service policies.
    pub invalidated_versions: Vec<(usize, u32)>,
    /// Per retrain step: `(lineage, coverage warm-started from)` — the
    /// resolved warm-start chain, the witness the serial-vs-parallel
    /// parity tests compare (0 = from scratch).
    pub warm_covers: Vec<(usize, u32)>,
}

/// How [`Engine::execute_plan`] schedules a plan's lineage chains.
/// Resolution semantics are identical either way (one [`ChainResolver`]
/// pass against the plan-time store snapshot); the mode only picks the
/// execution strategy — so `Serial` and `Parallel` produce the same RSN,
/// warm-start chains, and invalidation set for the same plan.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub enum ExecMode {
    /// Parallel when the backend hands out workers and the plan is big
    /// enough to amortize thread spawn; serial otherwise.
    #[default]
    Auto,
    /// Always on the engine thread.
    Serial,
    /// Parallel whenever the backend supports workers (regardless of plan
    /// size); falls back to serial when it does not.
    Parallel,
}

/// One step of a lineage's resolved retrain chain: clean one poisoned
/// sub-model version (Alg. 3 lines 8, 11–12).
struct ResolvedStep {
    /// Coverage of the retrained clean version: poisoned segment + 1.
    clean_cover: u32,
    /// Coverage of the model this step starts from (0 = scratch).
    warm_cover: u32,
    /// Checkpoint payload to warm-start from; `None` when chained onto
    /// the previous step's in-memory model or when starting from scratch.
    /// A refcount clone of the stored [`EncodedParams`] — never payload
    /// bytes. Decoding is deferred to the executor, which goes through the
    /// plan's [`DecodeCache`] right before the step resets the trainer, so
    /// a checkpoint referenced several times decodes once and at most one
    /// chain's tensors are dense in memory at a time.
    warm_start: Option<(CheckpointId, Arc<EncodedParams>)>,
    /// Continue from the previous step's retrained model — it already
    /// covers more than any stored checkpoint below the poisoned segment,
    /// so no trainer reset is needed.
    chained: bool,
    /// No usable checkpoint below the poisoned segment: full restart.
    scratch: bool,
    /// Replay set: live (block, samples) for the warm-start..clean range.
    replay: Vec<(BlockId, u64)>,
    /// Samples this step replays (the step's RSN contribution).
    rsn: u64,
}

/// A lineage's full retrain chain for one request/batch.
struct ResolvedChain {
    lineage: usize,
    steps: Vec<ResolvedStep>,
}

/// Resolves lineage plans into retrain chains against a store snapshot
/// taken at plan time (Alg. 3 line 8 per poisoned version). Both the
/// serial and the parallel executor resolve through this single type, so
/// they warm-start identically for the same plan — a plan's chains never
/// see the store mutations (retrained-checkpoint stores, evictions) made
/// while executing *other* chains of the same plan. Steps run in ascending
/// segment order; step i+1 warm-starts from step i's retrained model
/// unless the snapshot holds a strictly newer checkpoint (a later
/// sub-model version left in place, per the paper's retraining
/// accounting). When the refreshed checkpoint would have been rejected by
/// a full no-replacement store, chaining onto the in-memory model replays
/// strictly fewer samples with the same guarantee.
///
/// [`ChainResolver::rsn`] prices a chain without materializing anything:
/// warm covers come from the store's coverage index, replay sizes from the
/// lineage prefix sums — O(log) per step, zero allocation.
pub(crate) struct ChainResolver<'a> {
    store: &'a ModelStore,
    lineages: &'a LineageSet,
}

/// The warm-start decision of Alg. 3 line 8 for one step: newest stored
/// coverage below the poison, unless the previous step's in-memory model
/// is newer (chained), or nothing usable exists (scratch).
/// Returns (warm_cover, use_stored, chained, scratch).
fn warm_choice(best: Option<u32>, prev_clean: Option<u32>) -> (u32, bool, bool, bool) {
    match (best, prev_clean) {
        (Some(cov), Some(prev)) if cov > prev => (cov, true, false, false),
        (_, Some(prev)) => (prev, false, true, false),
        (Some(cov), None) => (cov, true, false, false),
        (None, None) => (0, false, false, true),
    }
}

impl<'a> ChainResolver<'a> {
    fn new(store: &'a ModelStore, lineages: &'a LineageSet) -> Self {
        Self { store, lineages }
    }

    /// Resolve one lineage's chain for execution: materializes the replay
    /// sets and clones the warm-start *payload refcounts* — decoding is
    /// deferred to the executor so resolution stays cheap and the dense
    /// tensors of at most one chain exist at a time.
    fn resolve(&self, lp: &LineagePlan) -> ResolvedChain {
        let mut steps = Vec::with_capacity(lp.segments.len());
        let mut prev_clean: Option<u32> = None;
        for &q in &lp.segments {
            let clean_cover = q as u32 + 1;
            let best = self.store.best_checkpoint(lp.lineage, q as u32);
            let (warm_cover, use_stored, chained, scratch) =
                warm_choice(best.map(|c| c.covered_segments), prev_clean);
            let warm_start = if use_stored {
                best.and_then(|c| c.params.clone().map(|p| (c.id, p)))
            } else {
                None
            };
            let replay =
                self.lineages.get(lp.lineage).replay_range(warm_cover, clean_cover);
            let rsn = replay.iter().map(|(_, n)| n).sum();
            steps.push(ResolvedStep {
                clean_cover,
                warm_cover,
                warm_start,
                chained,
                scratch,
                replay,
                rsn,
            });
            prev_clean = Some(clean_cover);
        }
        ResolvedChain { lineage: lp.lineage, steps }
    }

    /// Samples the lineage's chain would replay — the true coalesced
    /// retrain cost the battery admission gate reserves against. Pure
    /// index reads: no replay vectors, no parameter clones, no allocation.
    fn rsn(&self, lp: &LineagePlan) -> u64 {
        let l = self.lineages.get(lp.lineage);
        let mut prev_clean: Option<u32> = None;
        let mut total = 0;
        for &q in &lp.segments {
            let clean_cover = q as u32 + 1;
            let best =
                self.store.best_checkpoint(lp.lineage, q as u32).map(|c| c.covered_segments);
            let (warm_cover, _, _, _) = warm_choice(best, prev_clean);
            total += l.replay_range_samples(warm_cover, clean_cover);
            prev_clean = Some(clean_cover);
        }
        total
    }
}

/// Scan-resolved mirror of a plan's receipts, produced by
/// [`Engine::resolve_plan_naive`] without the store coverage index or the
/// lineage prefix sums — the differential oracle `bench_scale` and the
/// planner-equivalence tests compare the indexed path against.
#[derive(Clone, Debug, Default, PartialEq, Eq)]
pub struct NaivePlanResolution {
    /// Per plan lineage, in plan order: samples its chain replays.
    pub lineage_rsn: Vec<u64>,
    /// Per retrain step: `(lineage, warm-start coverage)` (0 = scratch).
    pub warm_covers: Vec<(usize, u32)>,
    /// Per retrain step: `(lineage, cleaned coverage)` — the sub-model
    /// versions execution will invalidate.
    pub invalidated_versions: Vec<(usize, u32)>,
}

/// Don't pay scoped-thread spawn/join for tiny plans: a plan must span
/// several lineages *and* clean at least this many sub-model versions in
/// total before the executor goes parallel. Typical FCFS requests (one or
/// two lineages, one poisoned segment each) stay serial on the `run_trace`
/// hot path; coalesced burst windows cross the bar.
const PARALLEL_MIN_VERSIONS: usize = 3;

/// Run one resolved chain through an off-thread [`LineageWorker`].
fn run_chain(
    worker: &mut dyn LineageWorker,
    chain: &ResolvedChain,
    epochs: u32,
    schedule: PruneSchedule,
) -> Result<Vec<TrainOutcome>> {
    chain
        .steps
        .iter()
        .map(|step| {
            if step.replay.is_empty() {
                Ok(TrainOutcome::default())
            } else {
                worker.run(&step.replay, epochs, schedule)
            }
        })
        .collect()
}

/// Outcome of one training round.
#[derive(Clone, Debug, Default)]
pub struct RoundReport {
    pub round: u32,
    pub shards_active: usize,
    pub lineages_trained: Vec<usize>,
    pub new_samples: u64,
    /// This round's placements with the owning user — what the durability
    /// journal records so recovery can replay `LineageSet::add_round`
    /// without the population or the partitioner.
    pub placements: Vec<(Placement, UserId)>,
}

/// The unlearning engine.
pub struct Engine {
    pub cfg: ExperimentConfig,
    partitioner: Box<dyn Partitioner>,
    sc: ShardController,
    store: ModelStore,
    lineages: LineageSet,
    trainer: Box<dyn Trainer>,
    schedule: PruneSchedule,
    energy: EnergyModel,
    /// Checkpoint payload codec (applies only to tensor-carrying backends;
    /// the accounting backend stores no tensors).
    codec: TensorCodec,
    pub metrics: RunMetrics,
    round: u32,
    eval: EvalPolicy,
    exec_mode: ExecMode,
    /// Lineages that ever received data (eligible for serving/eval).
    active: Vec<bool>,
    /// Sorted cache of the active lineage indices — kept incrementally so
    /// `evaluate()` never re-collects the set.
    active_list: Vec<usize>,
    /// When on, every store mutation is recorded so the durability journal
    /// can frame it into the current transition's event. Off by default —
    /// `durability = off` leaves the engine byte-identical.
    taping: bool,
    /// Store mutations since the last [`Engine::take_tape`].
    tape: Vec<StoreOpRec>,
}

impl Engine {
    #[allow(clippy::too_many_arguments)]
    pub fn new(
        cfg: ExperimentConfig,
        partitioner: Box<dyn Partitioner>,
        sc: ShardController,
        store: ModelStore,
        trainer: Box<dyn Trainer>,
        schedule: PruneSchedule,
        eval: EvalPolicy,
    ) -> Self {
        let energy = EnergyModel::for_model(&cfg.model);
        let codec = TensorCodec::new(cfg.codec);
        let max = cfg.shards;
        Self {
            cfg,
            partitioner,
            sc,
            store,
            lineages: LineageSet::new(max),
            trainer,
            schedule,
            energy,
            codec,
            metrics: RunMetrics::default(),
            round: 0,
            eval,
            exec_mode: ExecMode::Auto,
            active: vec![false; max],
            active_list: Vec::with_capacity(max),
            taping: false,
            tape: Vec::new(),
        }
    }

    pub fn round(&self) -> u32 {
        self.round
    }

    /// Force the plan executor's scheduling strategy (tests and the
    /// serial/parallel parity suite; deployments keep [`ExecMode::Auto`]).
    pub fn set_exec_mode(&mut self, mode: ExecMode) {
        self.exec_mode = mode;
    }

    pub fn exec_mode(&self) -> ExecMode {
        self.exec_mode
    }

    pub fn store(&self) -> &ModelStore {
        &self.store
    }

    pub fn lineages(&self) -> &LineageSet {
        &self.lineages
    }

    /// Lineages that ever received data, ascending. Served from an
    /// incrementally maintained cache — no per-call allocation.
    pub fn active_lineages(&self) -> &[usize] {
        &self.active_list
    }

    /// Execute one training round over the population's new data.
    pub fn run_round(&mut self, pop: &EdgePopulation) -> Result<RoundReport> {
        self.round += 1;
        let t = self.round;
        let s_t = self.sc.shards_at(t);
        let blocks = pop.blocks_at(t);
        let placements = self.partitioner.assign(blocks, s_t);
        debug_assert!(
            crate::partition::coverage_ok(blocks, &placements, s_t).is_ok(),
            "partitioner broke the coverage contract"
        );
        let touched =
            self.lineages.add_round(t, &placements, |b| pop.block(b).unwrap().user);

        let mut new_samples = 0;
        for &lineage in &touched {
            self.mark_active(lineage);
            let l = self.lineages.get(lineage);
            let covered = l.segment_count() - 1;
            let seg_blocks = l.replay_blocks(covered); // just the new segment
            new_samples += seg_blocks.iter().map(|(_, n)| n).sum::<u64>();
            let out = self.trainer.run(
                lineage,
                &seg_blocks,
                self.cfg.epochs_per_round,
                self.schedule,
            )?;
            self.metrics.prunes += out.prune_ops;
            self.metrics.energy_joules += self.energy.prune_joules(out.prune_ops);
            self.store_snapshot(lineage, t)?;
        }

        // Open this round's metric slots.
        self.metrics.rsn_by_round.push(0);
        self.metrics.requests_by_round.push(0);
        let acc = match self.eval {
            EvalPolicy::EveryRound => self.evaluate()?,
            EvalPolicy::FinalRound if t == self.cfg.rounds => self.evaluate()?,
            _ => None,
        };
        self.metrics.accuracy_by_round.push(acc);

        let placements = placements
            .into_iter()
            .map(|p| {
                let user = pop.block(p.block).unwrap().user;
                (p, user)
            })
            .collect();
        Ok(RoundReport {
            round: t,
            shards_active: s_t,
            lineages_trained: touched,
            new_samples,
            placements,
        })
    }

    /// Snapshot the lineage's current model and store it (Algorithm 2).
    fn store_snapshot(&mut self, lineage: usize, round: u32) -> Result<()> {
        let cover = self.lineages.get(lineage).segment_count();
        self.store_snapshot_with_coverage(lineage, round, cover)
    }

    /// Snapshot with an explicit coverage (retrained models cover only
    /// through the poisoned segment).
    fn store_snapshot_with_coverage(
        &mut self,
        lineage: usize,
        round: u32,
        covered_segments: u32,
    ) -> Result<()> {
        if !self.store.would_accept() {
            // A full no-replacement store would drop the checkpoint: skip
            // the snapshot (no param clone, no prune pass) but keep the
            // accounting and the id sequence identical to the
            // store-then-reject path.
            let id = self.store.next_id();
            self.store.record_rejection();
            self.metrics.ckpts_rejected += 1;
            if self.taping {
                self.tape.push(StoreOpRec::SkipReject { id: id.0 });
            }
            return Ok(());
        }
        let (size_hint, params) = self.trainer.snapshot(lineage)?;
        let (size_bytes, payload) = match params {
            // Accounting backend: no tensors, the backend's paper-scale
            // size formula stands.
            None => (size_hint, None),
            // Tensor-carrying backend: encode, and derive the stored size
            // from the actual encoding — not from a profile formula. The
            // delta base is the lineage's newest surviving payload
            // (post-invalidation during unlearning, last round's
            // checkpoint during training); the codec retains it by `Arc`
            // only when delta blocks actually pay.
            Some(p) => {
                let parent = self.store.latest(lineage).and_then(|c| c.params.clone());
                let enc = Arc::new(self.codec.encode(&p, parent.as_ref()));
                (enc.size_bytes(), Some(enc))
            }
        };
        let id = self.store.next_id();
        let payload_for_tape = if self.taping { payload.clone() } else { None };
        let ckpt = Checkpoint {
            id,
            lineage,
            round,
            covered_segments,
            size_bytes,
            params: payload,
        };
        let event = self.store.store(ckpt);
        match &event {
            StoreEvent::Stored { .. } => self.metrics.ckpts_stored += 1,
            StoreEvent::Replaced { .. } => {
                self.metrics.ckpts_stored += 1;
                self.metrics.ckpts_replaced += 1;
            }
            StoreEvent::Evicted { victims, .. } => {
                self.metrics.ckpts_stored += 1;
                self.metrics.ckpts_replaced += victims.len() as u64;
            }
            StoreEvent::Rejected => self.metrics.ckpts_rejected += 1,
        }
        if self.taping {
            self.tape.push(StoreOpRec::Store {
                id: id.0,
                lineage: lineage as u64,
                round,
                covered: covered_segments,
                size_bytes,
                payload: payload_for_tape,
                event: StoreEvRec::from_event(&event),
            });
        }
        Ok(())
    }

    /// Remove a request's samples from the lineage bookkeeping and report
    /// which `lineage → segments` were poisoned (Alg. 3 lines 7, 9–10).
    /// Pure poison collection: no retraining happens here, so a batch
    /// window can merge several requests' poison sets before replaying.
    pub fn collect_poison(&mut self, req: &UnlearnRequest) -> BTreeMap<usize, BTreeSet<usize>> {
        let mut poisoned: BTreeMap<usize, BTreeSet<usize>> = BTreeMap::new();
        for (block, n) in &req.parts {
            for (seg_ref, removed) in self.lineages.remove_samples(*block, *n) {
                if removed == 0 {
                    continue;
                }
                poisoned.entry(seg_ref.lineage).or_default().insert(seg_ref.segment);
            }
        }
        poisoned
    }

    /// True replay cost of a plan, per lineage, in the plan's lineage
    /// order: the samples each lineage's resolved chain will replay given
    /// the current store. One read-only, allocation-free index pass —
    /// this is the merged-cost probe the service's battery admission
    /// reserves against (a lineage touched by R requests is costed once,
    /// not R times; the probe runs once per window per admission retry),
    /// and it equals exactly what [`Engine::execute_plan`] will replay if
    /// run next (the resolver is shared, the cost model is deterministic).
    pub fn plan_lineage_rsn(&self, plan: &BatchPlan) -> Vec<u64> {
        let resolver = ChainResolver::new(&self.store, &self.lineages);
        plan.lineages.iter().map(|lp| resolver.rsn(lp)).collect()
    }

    /// Resolve a plan the way the pre-index planner did — O(slots) store
    /// scans and materialized replay vectors — and return the receipts
    /// execution would produce. Differential oracle only: `bench_scale`
    /// prices against it to measure the indexed speedup, and the
    /// equivalence tests assert [`Engine::plan_lineage_rsn`] and
    /// [`Engine::execute_plan`] match it byte for byte. Never called on a
    /// hot path.
    pub fn resolve_plan_naive(&self, plan: &BatchPlan) -> NaivePlanResolution {
        let mut out = NaivePlanResolution::default();
        for lp in &plan.lineages {
            let mut prev_clean: Option<u32> = None;
            let mut lineage_rsn = 0u64;
            for &q in &lp.segments {
                let clean_cover = q as u32 + 1;
                let best = self
                    .store
                    .best_checkpoint_scan(lp.lineage, q as u32)
                    .map(|c| c.covered_segments);
                let (warm_cover, _, _, _) = warm_choice(best, prev_clean);
                let replay =
                    self.lineages.get(lp.lineage).replay_range(warm_cover, clean_cover);
                lineage_rsn += replay.iter().map(|(_, n)| n).sum::<u64>();
                out.warm_covers.push((lp.lineage, warm_cover));
                out.invalidated_versions.push((lp.lineage, clean_cover));
                prev_clean = Some(clean_cover);
            }
            out.lineage_rsn.push(lineage_rsn);
        }
        out
    }

    /// Execute a batch plan: one retrain chain per affected lineage
    /// (Alg. 3 lines 8–12 per poisoned version). Every chain is resolved
    /// up front by one [`ChainResolver`] against the plan-time store
    /// snapshot — the serial and the parallel executor therefore produce
    /// identical warm-start chains, RSN, and invalidation sets; the
    /// [`ExecMode`] only decides whether independent lineages retrain on
    /// scoped threads (backend [`LineageWorker`]s; the cost model has
    /// them, PJRT's thread-local handles keep it serial) or on this
    /// thread. Store mutation and metric accounting always stay on this
    /// thread.
    ///
    /// Round-slot metrics (`rsn_by_round` / `requests_by_round`) are the
    /// caller's job via [`RunMetrics::record_requests`], since only the
    /// caller knows how many requests the plan merged.
    pub fn execute_plan(&mut self, plan: &BatchPlan) -> Result<UnlearnOutcome> {
        let mut outcome = UnlearnOutcome::default();
        if plan.is_empty() {
            return Ok(outcome);
        }
        let epochs = self.cfg.epochs_per_round;
        let schedule = self.schedule;
        let parallel = match self.exec_mode {
            ExecMode::Serial => false,
            ExecMode::Parallel => true,
            ExecMode::Auto => {
                plan.lineages.len() > 1
                    && plan.lineages.iter().map(|l| l.segments.len()).sum::<usize>()
                        >= PARALLEL_MIN_VERSIONS
            }
        };

        // All-or-nothing worker collection: the parallel path needs every
        // affected lineage to retrain off-thread.
        let mut workers: Vec<Box<dyn LineageWorker>> = Vec::new();
        let use_workers = parallel && {
            let mut all = true;
            for lp in &plan.lineages {
                match self.trainer.worker(lp.lineage) {
                    Some(w) => workers.push(w),
                    None => {
                        all = false;
                        break;
                    }
                }
            }
            if !all {
                workers.clear();
            }
            all
        };

        // One resolution pass for both executors (read-only). Warm-start
        // payloads are refcount clones of the stored checkpoints, so
        // holding every chain for the plan's duration costs pointers, not
        // tensors (the accounting backend stores no parameters at all).
        // Decoding happens lazily below, through a per-plan cache: a
        // checkpoint referenced several times while a chain executes
        // (warm starts, the serving restore) decodes once, and the cache
        // is released after each chain — checkpoints are lineage-scoped,
        // so cross-chain reuse is impossible and peak decoded memory is
        // one chain's, not the whole plan's.
        let mut cache = DecodeCache::default();
        let resolver = ChainResolver::new(&self.store, &self.lineages);
        let chains: Vec<ResolvedChain> =
            plan.lineages.iter().map(|lp| resolver.resolve(lp)).collect();

        if use_workers {
            // Independent lineages' retrains run on scoped threads.
            let results: Vec<Result<Vec<TrainOutcome>>> = std::thread::scope(|s| {
                let handles: Vec<_> = chains
                    .iter()
                    .zip(workers.iter_mut())
                    .map(|(chain, worker)| {
                        s.spawn(move || run_chain(&mut **worker, chain, epochs, schedule))
                    })
                    .collect();
                handles
                    .into_iter()
                    .map(|h| h.join().expect("retrain thread panicked"))
                    .collect()
            });
            for (chain, result) in chains.iter().zip(results) {
                let outs = result?;
                outcome.lineages_retrained += 1;
                let mut last_clean = 0;
                for (step, out) in chain.steps.iter().zip(&outs) {
                    self.trainer.absorb(chain.lineage, step.rsn, epochs, out);
                    self.apply_step(chain.lineage, step, out, &mut outcome)?;
                    last_clean = last_clean.max(step.clean_cover);
                }
                self.restore_serving_model(chain.lineage, last_clean, &mut cache)?;
                cache.release();
            }
        } else {
            // Serial: execute the pre-resolved chains one lineage at a
            // time on this thread. The per-step order is reset → run →
            // store, so the PJRT snapshot captures each step's model
            // before the next step moves it.
            for chain in &chains {
                outcome.lineages_retrained += 1;
                let mut last_clean = 0;
                for step in &chain.steps {
                    if !step.chained {
                        // Lazy decode: only now, on the step that actually
                        // resets, does the payload become dense tensors.
                        let decoded = step
                            .warm_start
                            .as_ref()
                            .map(|(id, p)| cache.decoded(id.0, p));
                        self.trainer.reset(chain.lineage, decoded.as_deref())?;
                    }
                    let out = if step.replay.is_empty() {
                        TrainOutcome::default()
                    } else {
                        self.trainer.run(chain.lineage, &step.replay, epochs, schedule)?
                    };
                    self.apply_step(chain.lineage, step, &out, &mut outcome)?;
                    last_clean = last_clean.max(step.clean_cover);
                }
                self.restore_serving_model(chain.lineage, last_clean, &mut cache)?;
                cache.release();
            }
        }

        // Alg. 3 accounting: retrain energy is linear in replayed samples.
        self.metrics.energy_joules += self.energy.retrain_joules(outcome.rsn, epochs);
        self.metrics.warm_retrains += outcome.warm_starts as u64;
        self.metrics.scratch_retrains += outcome.scratch_starts as u64;
        self.metrics.lineages_retrained += outcome.lineages_retrained as u64;
        self.metrics.ckpts_invalidated += outcome.ckpts_invalidated as u64;
        Ok(outcome)
    }

    /// Store-side effects of one executed retrain step: delete the
    /// poisoned sub-model version (Alg. 3 line 11), account the training
    /// outcome, and store the retrained model with its true coverage
    /// (line 12).
    fn apply_step(
        &mut self,
        lineage: usize,
        step: &ResolvedStep,
        out: &TrainOutcome,
        outcome: &mut UnlearnOutcome,
    ) -> Result<()> {
        let invalidated = self
            .store
            .invalidate_collect(|c| c.lineage == lineage && c.covered_segments == step.clean_cover);
        outcome.ckpts_invalidated += invalidated.len();
        if self.taping {
            self.tape.push(StoreOpRec::Invalidate {
                ids: invalidated.iter().map(|i| i.0).collect(),
            });
        }
        outcome.invalidated_versions.push((lineage, step.clean_cover));
        outcome.warm_covers.push((lineage, step.warm_cover));
        if step.scratch {
            outcome.scratch_starts += 1;
        } else {
            outcome.warm_starts += 1;
        }
        outcome.rsn += step.rsn;
        self.metrics.prunes += out.prune_ops;
        self.metrics.energy_joules += self.energy.prune_joules(out.prune_ops);
        self.store_snapshot_with_coverage(lineage, self.round, step.clean_cover)
    }

    /// Serving continuity: the deployed sub-model stays the newest version
    /// (the paper keeps later sub-model versions in place — DESIGN.md
    /// §Key-decisions); the retrain refreshed the *poisoned* versions.
    /// Restoring decodes through the plan's cache (at most once per
    /// checkpoint per plan) and hands the trainer a refcount of the
    /// decoded tensors, never a copy.
    fn restore_serving_model(
        &mut self,
        lineage: usize,
        last_clean: u32,
        cache: &mut DecodeCache,
    ) -> Result<()> {
        let newest = self
            .store
            .latest(lineage)
            .filter(|c| c.covered_segments > last_clean)
            .map(|c| (c.id, c.params.clone()));
        if let Some((id, payload)) = newest {
            let decoded = payload.map(|p| cache.decoded(id.0, &p));
            self.trainer.reset(lineage, decoded.as_deref())?;
        }
        Ok(())
    }

    // -- Durability glue (journal taping, replay, snapshots) ---------------

    /// Enable/disable store-mutation taping (the durability journal frames
    /// the tape into each transition's event). Off keeps every path
    /// byte-identical to the pre-durability engine.
    pub(crate) fn set_taping(&mut self, on: bool) {
        self.taping = on;
        if !on {
            self.tape.clear();
        }
    }

    /// Drain the store mutations recorded since the last call.
    pub(crate) fn take_tape(&mut self) -> Vec<StoreOpRec> {
        std::mem::take(&mut self.tape)
    }

    pub(crate) fn store_mut(&mut self) -> &mut ModelStore {
        &mut self.store
    }

    /// Mark a lineage active, keeping the sorted cache consistent.
    fn mark_active(&mut self, lineage: usize) {
        if !self.active[lineage] {
            self.active[lineage] = true;
            let at = self.active_list.partition_point(|&l| l < lineage);
            self.active_list.insert(at, lineage);
        }
    }

    /// Partitioner counters for the durability journal/snapshot.
    pub(crate) fn partitioner_state(&self) -> Vec<u64> {
        self.partitioner.persist_state()
    }

    pub(crate) fn restore_partitioner_state(&mut self, state: &[u64]) {
        self.partitioner.restore_state(state);
    }

    /// Replay one removal exactly as `collect_poison` performed it.
    pub(crate) fn replay_remove(&mut self, block: u64, n: u64) {
        let _ = self.lineages.remove_samples(BlockId(block), n);
    }

    /// Replay recorded store mutations (admissions with their exact
    /// victim sets, probe-skipped rejections, invalidations). Engine
    /// metrics are NOT touched here — the enclosing event carries them as
    /// absolute post-values.
    pub(crate) fn replay_store_ops(&mut self, ops: &[StoreOpRec]) {
        for op in ops {
            match op {
                StoreOpRec::Store { event, .. } => {
                    let ckpt = op.to_checkpoint().expect("Store op has a checkpoint");
                    self.store.apply_store_record(ckpt, &event.to_event());
                }
                StoreOpRec::SkipReject { id } => self.store.apply_skipped_rejection(*id),
                StoreOpRec::Invalidate { ids } => {
                    // An empty id set is a recorded no-op (live
                    // `invalidate_collect` found nothing and added 0).
                    if !ids.is_empty() {
                        let _ = self.store.invalidate(|c| ids.contains(&c.id.0));
                    }
                }
            }
        }
    }

    /// Replay one training round from its journal record: lineages,
    /// active set, store admissions, the accuracy slot. Round-slot metrics
    /// and scalar counters come from the event's absolute metric record
    /// (applied by the service).
    pub(crate) fn replay_round(&mut self, rec: &RoundRec) {
        self.round = rec.round;
        self.apply_recorded_placements(rec.round, &rec.placements);
        self.replay_store_ops(&rec.store_ops);
        self.metrics.accuracy_by_round.push(rec.accuracy);
        self.restore_partitioner_state(&rec.partitioner_state);
        self.store.restore_policy_state(&rec.policy_state);
    }

    /// Feed recorded placements through the real `add_round` so prefix
    /// sums, the block index, and the active set come out identical.
    fn apply_recorded_placements(&mut self, round: u32, placements: &[PlacementRecord]) {
        let placed: Vec<Placement> = placements
            .iter()
            .map(|p| Placement {
                block: BlockId(p.block),
                shard: p.shard as usize,
                samples: p.samples,
            })
            .collect();
        let users: BTreeMap<BlockId, UserId> = placements
            .iter()
            .map(|p| (BlockId(p.block), UserId(p.user)))
            .collect();
        let touched = self.lineages.add_round(round, &placed, |b| users[&b]);
        for lineage in touched {
            self.mark_active(lineage);
        }
    }

    /// Rebuild lineage state from a snapshot's per-round placements.
    pub(crate) fn restore_rounds(&mut self, rounds: &[(u32, Vec<PlacementRecord>)]) {
        for (round, placements) in rounds {
            self.apply_recorded_placements(*round, placements);
        }
    }

    pub(crate) fn set_round(&mut self, round: u32) {
        self.round = round;
    }

    /// Snapshot the lineage history as per-round placement records with
    /// *current* sample counts (unlearned data stays unlearned after the
    /// rebuild). Rounds ascending; within a round, lineages ascending in
    /// segment slot order — exactly the order `add_round` saw.
    pub(crate) fn capture_rounds(&self) -> Vec<(u32, Vec<PlacementRecord>)> {
        let mut rounds: BTreeMap<u32, Vec<PlacementRecord>> = BTreeMap::new();
        for li in 0..self.lineages.len() {
            for seg in self.lineages.get(li).segments() {
                let recs = seg.placements.iter().map(|p| PlacementRecord {
                    block: p.block.0,
                    user: p.user.0,
                    shard: li as u64,
                    samples: p.samples,
                });
                rounds.entry(seg.round).or_default().extend(recs);
            }
        }
        rounds.into_iter().collect()
    }

    /// Exact store state for a snapshot.
    pub(crate) fn capture_store_image(&self) -> StoreImage {
        let (mode_tag, mode_value) = match self.store.mode() {
            CapacityMode::Slots(n) => (0u8, n as u64),
            CapacityMode::Bytes(b) => (1u8, b),
        };
        let mut slots: Vec<Option<SlotCkpt>> = vec![None; self.store.capacity()];
        for (slot, c) in self.store.slot_entries() {
            slots[slot] = Some(SlotCkpt {
                id: c.id.0,
                lineage: c.lineage as u64,
                round: c.round,
                covered: c.covered_segments,
                size_bytes: c.size_bytes,
                payload: c.params.clone(),
            });
        }
        let st = self.store.stats();
        StoreImage {
            mode_tag,
            mode_value,
            next_id: self.store.next_id_peek(),
            stats: (st.stored, st.replaced, st.rejected, st.invalidated),
            slots,
            policy_state: self.store.policy_state(),
        }
    }

    /// Restore the store from a snapshot (the service validates that the
    /// engine was built with the same capacity mode).
    pub(crate) fn restore_store_image(&mut self, img: &StoreImage) {
        let slots: Vec<Option<Checkpoint>> = img
            .slots
            .iter()
            .map(|s| {
                s.as_ref().map(|c| Checkpoint {
                    id: CheckpointId(c.id),
                    lineage: c.lineage as usize,
                    round: c.round,
                    covered_segments: c.covered,
                    size_bytes: c.size_bytes,
                    params: c.payload.clone(),
                })
            })
            .collect();
        self.store.restore_slots(
            slots,
            img.next_id,
            StoreStats {
                stored: img.stats.0,
                replaced: img.stats.1,
                rejected: img.stats.2,
                invalidated: img.stats.3,
            },
        );
        self.store.restore_policy_state(&img.policy_state);
    }

    /// Serve one unlearning request (Algorithm 3 lines 7–12): a
    /// single-request plan through the shared batch machinery.
    pub fn process_request(&mut self, req: &UnlearnRequest) -> Result<UnlearnOutcome> {
        let plan = BatchPlan::collect(self, std::slice::from_ref(req));
        let outcome = self.execute_plan(&plan)?;
        self.metrics.record_requests(1, outcome.rsn);
        Ok(outcome)
    }

    /// Ensemble accuracy of the active lineages (real backend only).
    pub fn evaluate(&mut self) -> Result<Option<f64>> {
        self.trainer.evaluate(&self.active_list)
    }

    /// Drive the full trace: T rounds, serving each round's requests FCFS.
    pub fn run_trace(
        &mut self,
        pop: &EdgePopulation,
        trace: &RequestTrace,
    ) -> Result<&RunMetrics> {
        for t in 1..=self.cfg.rounds.min(pop.rounds()) {
            self.run_round(pop)?;
            for req in trace.at(t) {
                self.process_request(req)?;
            }
        }
        Ok(&self.metrics)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::dataset::UserId;
    use crate::memory::CheckpointId;
    use crate::partition::Placement;
    use crate::replacement::NoReplace;
    use crate::runtime::codec::CodecMode;

    /// Warm-start resolution shares checkpoint *payloads* by refcount
    /// (never payload bytes), and the executor-side decode goes through
    /// the plan cache exactly once per checkpoint — the decode-cached
    /// successor of the zero-copy refcount criterion.
    #[test]
    fn warm_start_shares_payload_refcounts_and_decodes_once() {
        let mut store = ModelStore::new(2, Box::new(NoReplace));
        let tensors =
            vec![HostTensor::from_fn(&[32, 32], |i| if i % 4 == 0 { 0.0 } else { i as f32 })];
        let codec = TensorCodec::new(CodecMode::Sparse);
        let payload = Arc::new(codec.encode(&tensors, None));
        let id = store.next_id();
        store.store(Checkpoint {
            id,
            lineage: 0,
            round: 1,
            covered_segments: 1,
            size_bytes: payload.size_bytes(),
            params: Some(payload.clone()),
        });

        let mut lineages = LineageSet::new(1);
        lineages.add_round(
            1,
            &[Placement { block: BlockId(0), shard: 0, samples: 10 }],
            |_| UserId(0),
        );
        lineages.add_round(
            2,
            &[Placement { block: BlockId(1), shard: 0, samples: 5 }],
            |_| UserId(0),
        );

        let resolver = ChainResolver::new(&store, &lineages);
        let lp = LineagePlan { lineage: 0, segments: vec![1], requests_touching: 1 };
        let chain = resolver.resolve(&lp);
        assert_eq!(chain.lineage, 0);
        assert_eq!(chain.steps.len(), 1);
        let (wid, enc) = chain.steps[0].warm_start.as_ref().expect("warm start has payload");
        assert!(Arc::ptr_eq(enc, &payload), "payload must share, not copy");
        // Strong counts: the store's copy, the test's handle, the chain's.
        assert_eq!(Arc::strong_count(&payload), 3);
        assert_eq!(chain.steps[0].warm_cover, 1);
        // Executor-side decode: once per checkpoint per plan, shared by
        // refcount afterwards; release() scopes the dense memory without
        // losing the statistics.
        let mut cache = DecodeCache::default();
        let a = cache.decoded(wid.0, enc);
        let b = cache.decoded(wid.0, enc);
        assert_eq!(a.as_ref(), tensors.as_slice(), "decode must be exact");
        assert!(Arc::ptr_eq(&a, &b), "per-plan cache must share decodes");
        assert_eq!((cache.decodes, cache.hits), (1, 1));
        cache.release();
        assert_eq!(cache.decoded(wid.0, enc).as_ref(), tensors.as_slice());
        assert_eq!(cache.decodes, 2);
        // The allocation-free probe prices the same chain identically and
        // never decodes anything.
        assert_eq!(
            resolver.rsn(&lp),
            chain.steps.iter().map(|s| s.rsn).sum::<u64>()
        );
        assert_eq!(resolver.rsn(&lp), 5);
    }

    /// The indexed probe and the scan oracle agree on a handcrafted
    /// multi-step chain (chained + stored warm starts mixed).
    #[test]
    fn indexed_probe_matches_naive_choice_logic() {
        let mut store = ModelStore::new(4, Box::new(NoReplace));
        for (round, cover) in [(1u32, 1u32), (3, 3)] {
            let id = store.next_id();
            store.store(Checkpoint {
                id,
                lineage: 0,
                round,
                covered_segments: cover,
                size_bytes: 1,
                params: None,
            });
        }
        let mut lineages = LineageSet::new(1);
        for r in 1..=4u32 {
            lineages.add_round(
                r,
                &[Placement { block: BlockId(r as u64), shard: 0, samples: 10 * r as u64 }],
                |_| UserId(0),
            );
        }
        let resolver = ChainResolver::new(&store, &lineages);
        // Poisoned segments 1 and 3: step 1 warm-starts from cover 1,
        // step 2 from the stored cover-3 checkpoint (newer than the
        // in-memory cover-2 model).
        let lp = LineagePlan { lineage: 0, segments: vec![1, 3], requests_touching: 1 };
        let chain = resolver.resolve(&lp);
        let covers: Vec<u32> = chain.steps.iter().map(|s| s.warm_cover).collect();
        assert_eq!(covers, vec![1, 3]);
        // Step RSN: segments [1,2) = 20; segments [3,4) = 40.
        assert_eq!(chain.steps[0].rsn, 20);
        assert_eq!(chain.steps[1].rsn, 40);
        assert_eq!(resolver.rsn(&lp), 60);
        // max_by_key tie-break parity between index and scan.
        assert_eq!(
            store.best_checkpoint(0, 3).map(|c| c.id),
            store.best_checkpoint_scan(0, 3).map(|c| c.id)
        );
        assert_eq!(store.best_checkpoint(0, 3).unwrap().id, CheckpointId(1));
    }
}
