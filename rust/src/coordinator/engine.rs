//! The exact-unlearning engine — Algorithm 3 of the paper, generalized so
//! CAUSE and every baseline are configuration points of the same loop.
//!
//! Per round t (Algorithm 3 lines 1–5):
//!   1. the shard controller yields S_t;
//!   2. the partitioner assigns the round's new blocks to shard lineages;
//!   3. every touched lineage trains incrementally on its new segment
//!      (with the system's pruning schedule interleaved — RCMP);
//!   4. the resulting sub-model checkpoint is stored per the replacement
//!      policy (FiboR for CAUSE; reject-when-full for SISA/ARCANE/OMP).
//!
//! Per unlearning request (lines 6–12):
//!   1. the affected lineages and their earliest poisoned segments are
//!      located through the block index;
//!   2. the unlearned samples are removed from the lineage bookkeeping;
//!   3. every stored checkpoint containing poisoned data is deleted
//!      (line 11);
//!   4. each affected lineage retrains from the newest surviving
//!      checkpoint that predates the poison (line 8) — or from scratch —
//!      and the retrained model is stored again via the policy (line 12);
//!   5. RSN += samples replayed — the paper's headline metric.

use std::collections::BTreeMap;

use anyhow::Result;

use crate::config::ExperimentConfig;
use crate::coordinator::lineage::LineageSet;
use crate::data::dataset::EdgePopulation;
use crate::data::trace::{RequestTrace, UnlearnRequest};
use crate::energy::EnergyModel;
use crate::memory::{Checkpoint, ModelStore, StoreEvent};
use crate::metrics::RunMetrics;
use crate::partition::Partitioner;
use crate::pruning::PruneSchedule;
use crate::shard_controller::ShardController;
use crate::training::Trainer;

/// When the engine measures ensemble accuracy (PJRT backend only).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum EvalPolicy {
    Never,
    FinalRound,
    EveryRound,
}

/// Outcome of one unlearning request.
#[derive(Clone, Debug, Default)]
pub struct UnlearnOutcome {
    pub rsn: u64,
    pub lineages_retrained: usize,
    pub warm_starts: usize,
    pub scratch_starts: usize,
    pub ckpts_invalidated: usize,
}

/// Outcome of one training round.
#[derive(Clone, Debug, Default)]
pub struct RoundReport {
    pub round: u32,
    pub shards_active: usize,
    pub lineages_trained: Vec<usize>,
    pub new_samples: u64,
}

/// The unlearning engine.
pub struct Engine {
    pub cfg: ExperimentConfig,
    partitioner: Box<dyn Partitioner>,
    sc: ShardController,
    store: ModelStore,
    lineages: LineageSet,
    trainer: Box<dyn Trainer>,
    schedule: PruneSchedule,
    energy: EnergyModel,
    pub metrics: RunMetrics,
    round: u32,
    eval: EvalPolicy,
    /// Lineages that ever received data (eligible for serving/eval).
    active: Vec<bool>,
}

impl Engine {
    #[allow(clippy::too_many_arguments)]
    pub fn new(
        cfg: ExperimentConfig,
        partitioner: Box<dyn Partitioner>,
        sc: ShardController,
        store: ModelStore,
        trainer: Box<dyn Trainer>,
        schedule: PruneSchedule,
        eval: EvalPolicy,
    ) -> Self {
        let energy = EnergyModel::for_model(&cfg.model);
        let max = cfg.shards;
        Self {
            cfg,
            partitioner,
            sc,
            store,
            lineages: LineageSet::new(max),
            trainer,
            schedule,
            energy,
            metrics: RunMetrics::default(),
            round: 0,
            eval,
            active: vec![false; max],
        }
    }

    pub fn round(&self) -> u32 {
        self.round
    }

    pub fn store(&self) -> &ModelStore {
        &self.store
    }

    pub fn lineages(&self) -> &LineageSet {
        &self.lineages
    }

    pub fn active_lineages(&self) -> Vec<usize> {
        self.active
            .iter()
            .enumerate()
            .filter(|(_, a)| **a)
            .map(|(i, _)| i)
            .collect()
    }

    /// Execute one training round over the population's new data.
    pub fn run_round(&mut self, pop: &EdgePopulation) -> Result<RoundReport> {
        self.round += 1;
        let t = self.round;
        let s_t = self.sc.shards_at(t);
        let blocks = pop.blocks_at(t);
        let placements = self.partitioner.assign(blocks, s_t);
        debug_assert!(
            crate::partition::coverage_ok(blocks, &placements, s_t).is_ok(),
            "partitioner broke the coverage contract"
        );
        let touched =
            self.lineages.add_round(t, &placements, |b| pop.block(b).unwrap().user);

        let mut new_samples = 0;
        for &lineage in &touched {
            self.active[lineage] = true;
            let l = self.lineages.get(lineage);
            let covered = l.segment_count() - 1;
            let seg_blocks = l.replay_blocks(covered); // just the new segment
            new_samples += seg_blocks.iter().map(|(_, n)| n).sum::<u64>();
            let out = self.trainer.run(
                lineage,
                &seg_blocks,
                self.cfg.epochs_per_round,
                self.schedule,
            )?;
            self.metrics.prunes += out.prune_ops;
            self.metrics.energy_joules += self.energy.prune_joules(out.prune_ops);
            self.store_snapshot(lineage, t)?;
        }

        // Open this round's metric slots.
        self.metrics.rsn_by_round.push(0);
        self.metrics.requests_by_round.push(0);
        let acc = match self.eval {
            EvalPolicy::EveryRound => self.evaluate()?,
            EvalPolicy::FinalRound if t == self.cfg.rounds => self.evaluate()?,
            _ => None,
        };
        self.metrics.accuracy_by_round.push(acc);

        Ok(RoundReport {
            round: t,
            shards_active: s_t,
            lineages_trained: touched,
            new_samples,
        })
    }

    /// Snapshot the lineage's current model and store it (Algorithm 2).
    fn store_snapshot(&mut self, lineage: usize, round: u32) -> Result<()> {
        let cover = self.lineages.get(lineage).segment_count();
        self.store_snapshot_with_coverage(lineage, round, cover)
    }

    /// Snapshot with an explicit coverage (retrained models cover only
    /// through the poisoned segment).
    fn store_snapshot_with_coverage(
        &mut self,
        lineage: usize,
        round: u32,
        covered_segments: u32,
    ) -> Result<()> {
        let (size, params) = self.trainer.snapshot(lineage)?;
        let id = self.store.next_id();
        let ckpt = Checkpoint {
            id,
            lineage,
            round,
            covered_segments,
            size_bytes: size,
            params,
        };
        match self.store.store(ckpt) {
            StoreEvent::Stored { .. } => self.metrics.ckpts_stored += 1,
            StoreEvent::Replaced { .. } => {
                self.metrics.ckpts_stored += 1;
                self.metrics.ckpts_replaced += 1;
            }
            StoreEvent::Rejected => self.metrics.ckpts_rejected += 1,
        }
        Ok(())
    }

    /// Serve one unlearning request (Algorithm 3 lines 7–12).
    pub fn process_request(&mut self, req: &UnlearnRequest) -> Result<UnlearnOutcome> {
        let mut outcome = UnlearnOutcome::default();

        // 1. Remove the samples and collect each affected lineage's
        //    poisoned segment indices.
        let mut poisoned: BTreeMap<usize, Vec<usize>> = BTreeMap::new();
        for (block, n) in &req.parts {
            for (seg_ref, removed) in self.lineages.remove_samples(*block, *n) {
                if removed == 0 {
                    continue;
                }
                let segs = poisoned.entry(seg_ref.lineage).or_default();
                if !segs.contains(&seg_ref.segment) {
                    segs.push(seg_ref.segment);
                }
            }
        }

        // 2. For every poisoned sub-model version, retrain from the newest
        //    surviving checkpoint that predates it (Alg. 3 line 8: "the
        //    sub-model most closely to the unlearned data before D_r is
        //    learned"), replaying through the poisoned segment. Later
        //    sub-model versions stay in place — the paper's retraining
        //    accounting (see DESIGN.md §Key-decisions).
        for (lineage, mut segs) in poisoned {
            segs.sort_unstable();
            outcome.lineages_retrained += 1;
            let mut last_clean_cover = 0;
            for q in segs {
                let max_cover = q as u32; // checkpoint must cover < segment q
                let clean_cover = q as u32 + 1; // retrained version's coverage
                let best = self
                    .store
                    .best_checkpoint(lineage, max_cover)
                    .map(|c| (c.covered_segments, c.params.clone()));

                // Algorithm 3 line 11: delete the sub-model version that
                // learned the unlearned data; the retrained clean model
                // replaces it.
                outcome.ckpts_invalidated += self.store.invalidate(|c| {
                    c.lineage == lineage && c.covered_segments == clean_cover
                });

                let (covered, warm_params) = match best {
                    Some((cov, params)) => {
                        outcome.warm_starts += 1;
                        (cov, params)
                    }
                    None => {
                        outcome.scratch_starts += 1;
                        (0, None)
                    }
                };
                let replay =
                    self.lineages.get(lineage).replay_range(covered, clean_cover);
                let rsn: u64 = replay.iter().map(|(_, n)| n).sum();
                outcome.rsn += rsn;

                self.trainer.reset(lineage, warm_params.as_deref())?;
                if !replay.is_empty() {
                    let out = self.trainer.run(
                        lineage,
                        &replay,
                        self.cfg.epochs_per_round,
                        self.schedule,
                    )?;
                    self.metrics.prunes += out.prune_ops;
                    self.metrics.energy_joules += self.energy.prune_joules(out.prune_ops);
                }
                // Algorithm 3 line 12: store the retrained sub-model with
                // its true coverage (clean through segment q).
                self.store_snapshot_with_coverage(lineage, self.round, clean_cover)?;
                last_clean_cover = last_clean_cover.max(clean_cover);
            }
            // Serving continuity: the deployed sub-model stays the newest
            // version (the paper keeps later sub-model versions in place —
            // see DESIGN.md §Key-decisions); the retrain above refreshed
            // the *poisoned* version's checkpoint.
            let newest = self
                .store
                .latest(lineage)
                .filter(|c| c.covered_segments > last_clean_cover)
                .map(|c| c.params.clone());
            if let Some(params) = newest {
                self.trainer.reset(lineage, params.as_deref())?;
            }
        }

        // 3. Account.
        self.metrics.energy_joules +=
            self.energy.retrain_joules(outcome.rsn, self.cfg.epochs_per_round);
        if let Some(last) = self.metrics.rsn_by_round.last_mut() {
            *last += outcome.rsn;
        }
        if let Some(last) = self.metrics.requests_by_round.last_mut() {
            *last += 1;
        }
        self.metrics.warm_retrains += outcome.warm_starts as u64;
        self.metrics.scratch_retrains += outcome.scratch_starts as u64;
        self.metrics.lineages_retrained += outcome.lineages_retrained as u64;
        self.metrics.ckpts_invalidated += outcome.ckpts_invalidated as u64;
        Ok(outcome)
    }

    /// Ensemble accuracy of the active lineages (real backend only).
    pub fn evaluate(&mut self) -> Result<Option<f64>> {
        let active = self.active_lineages();
        self.trainer.evaluate(&active)
    }

    /// Drive the full trace: T rounds, serving each round's requests FCFS.
    pub fn run_trace(
        &mut self,
        pop: &EdgePopulation,
        trace: &RequestTrace,
    ) -> Result<&RunMetrics> {
        for t in 1..=self.cfg.rounds.min(pop.rounds()) {
            self.run_round(pop)?;
            for req in trace.at(t) {
                self.process_request(req)?;
            }
            let _ = t;
        }
        Ok(&self.metrics)
    }
}
