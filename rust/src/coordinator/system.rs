//! System presets: CAUSE and every benchmark system as configuration
//! points of the shared [`Engine`].
//!
//! | System      | Partition   | Replacement | Pruning            | SC  |
//! |-------------|-------------|-------------|--------------------|-----|
//! | CAUSE       | UCDP        | FiboR       | RCMP δ=70% (iter.) | on  |
//! | CAUSE-No-SC | UCDP        | FiboR       | RCMP δ=70%         | off |
//! | CAUSE-U     | uniform     | FiboR       | RCMP δ=70%         | on  |
//! | CAUSE-C     | class-based | FiboR       | RCMP δ=70%         | on  |
//! | SISA        | uniform     | none        | none               | off |
//! | ARCANE      | class-based | none        | none               | off |
//! | OMP-70      | uniform     | none        | one-shot δ=70%     | off |
//! | OMP-95      | uniform     | none        | one-shot δ=95%     | off |

use anyhow::Result;

use crate::config::ExperimentConfig;
use crate::coordinator::engine::{Engine, EvalPolicy};
use crate::fleet::FleetService;
use crate::memory::{ModelStore, StoreMeter};
use crate::partition::{ClassBased, Partitioner, Ucdp, Uniform};
use crate::persist::{DiskFs, Durability, DurabilityMode, FileSpool};
use crate::pruning::PruneSchedule;
use crate::replacement::{FiboR, NoReplace, RandomReplace, ReplacementPolicy};
use crate::shard_controller::ShardController;
use crate::training::{CostTrainer, Trainer};
use crate::unlearning::{BatchPlanner, BatchPolicy, UnlearningService};

/// The systems compared throughout §5 of the paper.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum SystemVariant {
    Cause,
    CauseNoSc,
    CauseU,
    CauseC,
    /// CAUSE with random replacement instead of FiboR (§4.4 remark).
    CauseRandomReplace,
    Sisa,
    Arcane,
    Omp70,
    Omp95,
}

impl SystemVariant {
    pub fn display(&self) -> &'static str {
        match self {
            SystemVariant::Cause => "CAUSE",
            SystemVariant::CauseNoSc => "CAUSE-No-SC",
            SystemVariant::CauseU => "CAUSE-U",
            SystemVariant::CauseC => "CAUSE-C",
            SystemVariant::CauseRandomReplace => "CAUSE-Rand",
            SystemVariant::Sisa => "SISA",
            SystemVariant::Arcane => "ARCANE",
            SystemVariant::Omp70 => "OMP-70",
            SystemVariant::Omp95 => "OMP-95",
        }
    }

    /// The five headline systems of the evaluation section.
    pub const COMPARED: [SystemVariant; 5] = [
        SystemVariant::Cause,
        SystemVariant::Sisa,
        SystemVariant::Arcane,
        SystemVariant::Omp70,
        SystemVariant::Omp95,
    ];

    pub fn by_name(name: &str) -> Option<SystemVariant> {
        match name.to_ascii_lowercase().as_str() {
            "cause" => Some(SystemVariant::Cause),
            "cause-no-sc" | "cause_no_sc" => Some(SystemVariant::CauseNoSc),
            "cause-u" | "cause_u" => Some(SystemVariant::CauseU),
            "cause-c" | "cause_c" => Some(SystemVariant::CauseC),
            "cause-rand" | "cause_rand" => Some(SystemVariant::CauseRandomReplace),
            "sisa" => Some(SystemVariant::Sisa),
            "arcane" => Some(SystemVariant::Arcane),
            "omp-70" | "omp70" => Some(SystemVariant::Omp70),
            "omp-95" | "omp95" => Some(SystemVariant::Omp95),
            _ => None,
        }
    }

    /// Pruning schedule of this system, given the config's δ for CAUSE.
    pub fn schedule(&self, cfg: &ExperimentConfig) -> PruneSchedule {
        match self {
            SystemVariant::Cause
            | SystemVariant::CauseNoSc
            | SystemVariant::CauseU
            | SystemVariant::CauseC
            | SystemVariant::CauseRandomReplace => {
                PruneSchedule::Iterative { keep: cfg.prune_keep, steps: 4 }
            }
            SystemVariant::Sisa | SystemVariant::Arcane => PruneSchedule::None,
            SystemVariant::Omp70 => PruneSchedule::OneShot { keep: 0.3 },
            SystemVariant::Omp95 => PruneSchedule::OneShot { keep: 0.05 },
        }
    }

    fn partitioner(&self, cfg: &ExperimentConfig) -> Box<dyn Partitioner> {
        match self {
            SystemVariant::Cause
            | SystemVariant::CauseNoSc
            | SystemVariant::CauseRandomReplace => {
                Box::new(Ucdp::new(cfg.shards, cfg.seed ^ 0x0c0de))
            }
            SystemVariant::CauseU | SystemVariant::Sisa | SystemVariant::Omp70
            | SystemVariant::Omp95 => Box::new(Uniform::new(cfg.shards)),
            SystemVariant::CauseC | SystemVariant::Arcane => {
                Box::new(ClassBased::new(cfg.dataset.classes))
            }
        }
    }

    fn replacement(&self, cfg: &ExperimentConfig) -> Box<dyn ReplacementPolicy> {
        match self {
            SystemVariant::Cause
            | SystemVariant::CauseNoSc
            | SystemVariant::CauseU
            | SystemVariant::CauseC => Box::new(FiboR::new()),
            SystemVariant::CauseRandomReplace => {
                Box::new(RandomReplace::new(cfg.seed ^ 0x7a7d))
            }
            SystemVariant::Sisa
            | SystemVariant::Arcane
            | SystemVariant::Omp70
            | SystemVariant::Omp95 => Box::new(NoReplace),
        }
    }

    fn shard_controller(&self, cfg: &ExperimentConfig) -> ShardController {
        match self {
            SystemVariant::Cause
            | SystemVariant::CauseU
            | SystemVariant::CauseC
            | SystemVariant::CauseRandomReplace => {
                ShardController::new(cfg.shards, cfg.sc_gamma, cfg.sc_p)
            }
            _ => ShardController::disabled(cfg.shards),
        }
    }

    /// Build the engine with an explicit trainer (PJRT or cost).
    pub fn build_with_trainer(
        &self,
        cfg: &ExperimentConfig,
        trainer: Box<dyn Trainer>,
        eval: EvalPolicy,
    ) -> Result<Engine> {
        cfg.validate()?;
        let store = match cfg.store_meter {
            // Paper baseline: C_m normalized to N_mem slots of one
            // (worst-case) checkpoint each.
            StoreMeter::Slots => {
                let slots =
                    ((cfg.memory_bytes / trainer.checkpoint_bytes().max(1)) as usize).max(1);
                ModelStore::new(slots, self.replacement(cfg))
            }
            // Bytes are the currency: admission and eviction reason in
            // each checkpoint's true encoded size.
            StoreMeter::Bytes => {
                ModelStore::with_byte_budget(cfg.memory_bytes.max(1), self.replacement(cfg))
            }
        };
        Ok(Engine::new(
            cfg.clone(),
            self.partitioner(cfg),
            self.shard_controller(cfg),
            store,
            trainer,
            self.schedule(cfg),
            eval,
        ))
    }

    /// Build with the accounting backend (RSN / energy experiments).
    pub fn build_cost(&self, cfg: &ExperimentConfig) -> Result<Engine> {
        let trainer = CostTrainer::new(cfg.model, self.schedule(cfg));
        self.build_with_trainer(cfg, Box::new(trainer), EvalPolicy::Never)
    }

    /// Service batching policy for this system: the CAUSE family honors
    /// the config's policy (coalescing by default); the baselines stay
    /// strictly FCFS — that is their papers' service model, and keeping
    /// them there makes the RSN comparison a like-for-like reproduction.
    pub fn batch_policy(&self, cfg: &ExperimentConfig) -> BatchPolicy {
        match self {
            SystemVariant::Cause
            | SystemVariant::CauseNoSc
            | SystemVariant::CauseU
            | SystemVariant::CauseC
            | SystemVariant::CauseRandomReplace => cfg.batch_policy,
            SystemVariant::Sisa
            | SystemVariant::Arcane
            | SystemVariant::Omp70
            | SystemVariant::Omp95 => BatchPolicy::Fcfs,
        }
    }

    /// Build the queue-fronted unlearning service for this system (cost
    /// backend), with the batch planner this system should run. When the
    /// config enables durability, the service recovers whatever state
    /// `persist_dir` holds (crash restart) and arms the write-ahead log
    /// before returning.
    pub fn build_service(&self, cfg: &ExperimentConfig) -> Result<UnlearningService> {
        let engine = self.build_cost(cfg)?;
        let planner = BatchPlanner::new(self.batch_policy(cfg), cfg.batch_window);
        let mut svc = UnlearningService::new(engine).with_planner(planner);
        if cfg.durability != DurabilityMode::Off {
            svc.attach_durability(
                Durability::disk(cfg.durability, &cfg.persist_dir, cfg.compact_every)?
                    .with_fsync(cfg.fsync),
            )?;
        }
        // After durability: recovery replay stays untraced, so a restarted
        // service's trace starts at the crash point, not at tick 0.
        if cfg.obs {
            svc.enable_obs();
        }
        Ok(svc)
    }

    /// Build the sharded fleet service: `cfg.fleet_workers` shard workers
    /// (cost backend), each a full [`build_service`]-shaped stack — same
    /// planner, its own engine seeded from
    /// [`FleetService::derive_shard_seeds`] — behind the routing front
    /// end. With durability enabled each shard journals under
    /// `persist_dir/shard-<k>/` (a 1-worker fleet reuses `persist_dir`
    /// itself, staying drop-in compatible with unsharded WALs).
    ///
    /// `fleet_workers = 1` builds a fleet that replays
    /// [`build_service`]'s output byte-identically.
    ///
    /// [`build_service`]: SystemVariant::build_service
    pub fn build_fleet(&self, cfg: &ExperimentConfig) -> Result<FleetService> {
        cfg.validate()?;
        let n = cfg.fleet_workers;
        let seeds = FleetService::derive_shard_seeds(cfg.seed, n);
        let variant = *self;
        let policy = self.batch_policy(cfg);
        let window = cfg.batch_window;
        let builders = seeds
            .iter()
            .enumerate()
            .map(|(k, &seed)| {
                let mut shard_cfg = cfg.clone();
                shard_cfg.seed = seed;
                // Durability is attached per-shard by the fleet below.
                shard_cfg.durability = DurabilityMode::Off;
                // `Fn`, not `FnOnce`: failover reruns a shard's builder.
                Box::new(move || {
                    let engine = variant.build_cost(&shard_cfg)?;
                    let mut svc = UnlearningService::new(engine)
                        .with_planner(BatchPlanner::new(policy, window));
                    svc.set_shard_tag(k as u32);
                    if shard_cfg.obs {
                        svc.enable_obs();
                    }
                    Ok(svc)
                }) as Box<dyn Fn() -> Result<UnlearningService> + Send + Sync>
            })
            .collect();
        let mut fleet = FleetService::new(builders, cfg.seed)?;
        if cfg.obs {
            fleet.enable_obs();
        }
        if cfg.durability != DurabilityMode::Off {
            fleet.attach_durability_disk(
                cfg.durability,
                &cfg.persist_dir,
                cfg.compact_every,
                cfg.fsync,
            )?;
            if cfg.ship_to_peer && n > 1 {
                match &cfg.ship_spool_dir {
                    // File-backed spool: shipped frames land on disk
                    // under `dir`, survive process death, and failover
                    // recovers a shard from the spool alone.
                    Some(dir) => {
                        let spool = FileSpool::open(Box::new(DiskFs::new(dir)?));
                        let source = spool.clone();
                        fleet.enable_log_shipping_custom(
                            std::sync::Arc::new(source),
                            move |_k| Box::new(spool.clone()),
                        )?;
                    }
                    None => {
                        fleet.enable_log_shipping()?;
                    }
                }
            }
        }
        Ok(fleet)
    }
}

/// Convenience façade used by the examples: a ready-to-run CAUSE system.
pub struct CauseSystem;

impl CauseSystem {
    /// CAUSE with the paper's default configuration (cost backend).
    pub fn default_engine(cfg: &ExperimentConfig) -> Result<Engine> {
        SystemVariant::Cause.build_cost(cfg)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn name_lookup() {
        for v in SystemVariant::COMPARED {
            assert_eq!(SystemVariant::by_name(v.display()), Some(v));
        }
        assert!(SystemVariant::by_name("bogus").is_none());
    }

    #[test]
    fn schedules_match_table6() {
        let cfg = ExperimentConfig::default();
        assert_eq!(SystemVariant::Sisa.schedule(&cfg), PruneSchedule::None);
        assert_eq!(
            SystemVariant::Omp95.schedule(&cfg),
            PruneSchedule::OneShot { keep: 0.05 }
        );
        match SystemVariant::Cause.schedule(&cfg) {
            PruneSchedule::Iterative { keep, .. } => assert!((keep - 0.3).abs() < 1e-12),
            other => panic!("CAUSE should prune iteratively, got {other:?}"),
        }
    }

    #[test]
    fn cause_fits_more_checkpoints_than_sisa() {
        let cfg = ExperimentConfig::default();
        let cause = SystemVariant::Cause.build_cost(&cfg).unwrap();
        let sisa = SystemVariant::Sisa.build_cost(&cfg).unwrap();
        assert!(
            cause.store().capacity() > sisa.store().capacity() * 2,
            "CAUSE {} vs SISA {}",
            cause.store().capacity(),
            sisa.store().capacity()
        );
    }

    #[test]
    fn build_validates_config() {
        let mut cfg = ExperimentConfig::default();
        cfg.shards = 0;
        assert!(SystemVariant::Cause.build_cost(&cfg).is_err());
    }

    #[test]
    fn baselines_stay_fcfs() {
        let cfg = ExperimentConfig::default(); // batch_policy = Coalesce
        assert_eq!(SystemVariant::Cause.batch_policy(&cfg), BatchPolicy::Coalesce);
        assert_eq!(SystemVariant::Sisa.batch_policy(&cfg), BatchPolicy::Fcfs);
        assert_eq!(SystemVariant::Arcane.batch_policy(&cfg), BatchPolicy::Fcfs);
        let svc = SystemVariant::Cause.build_service(&cfg).unwrap();
        assert_eq!(svc.planner().policy, BatchPolicy::Coalesce);
        let svc = SystemVariant::Omp70.build_service(&cfg).unwrap();
        assert_eq!(svc.planner().policy, BatchPolicy::Fcfs);
    }

    #[test]
    fn build_fleet_validates_and_constructs() {
        let mut cfg = ExperimentConfig::default();
        cfg.fleet_workers = 0;
        assert!(SystemVariant::Cause.build_fleet(&cfg).is_err());
        cfg.fleet_workers = 2;
        let fleet = SystemVariant::Cause.build_fleet(&cfg).unwrap();
        assert_eq!(fleet.workers(), 2);
        // Shard 0 runs the root seed; shard 1 a derived, distinct stream.
        assert_eq!(fleet.shard_seeds()[0], cfg.seed);
        assert_ne!(fleet.shard_seeds()[1], cfg.seed);
        assert_eq!(
            fleet.shard_seeds(),
            FleetService::derive_shard_seeds(cfg.seed, 2).as_slice()
        );
    }

    #[test]
    fn baselines_stay_fcfs_under_deadline_config() {
        // A deadline SLO is a CAUSE service feature; the baseline papers'
        // FCFS service model stays pinned for like-for-like RSN numbers.
        let cfg = ExperimentConfig::default().with_slo(4);
        assert_eq!(
            SystemVariant::Cause.batch_policy(&cfg),
            BatchPolicy::Deadline { slo_ticks: 4 }
        );
        for v in [SystemVariant::Sisa, SystemVariant::Arcane, SystemVariant::Omp95] {
            assert_eq!(v.batch_policy(&cfg), BatchPolicy::Fcfs);
        }
        let svc = SystemVariant::Cause.build_service(&cfg).unwrap();
        assert_eq!(svc.planner().policy.slo(), Some(4));
    }
}
