//! Shard lineages: which sub-model learned which data, in what order.
//!
//! A *lineage* is one shard's training history — a sequence of segments,
//! one per round in which the shard received data. A checkpoint taken after
//! segment k covers segments `0..=k` (incremental training, the paper's
//! Fig. 1 semantics: M2 is M1 plus D2). Unlearning data that entered at
//! segment p invalidates every checkpoint covering p and restarts training
//! from the newest stored checkpoint covering `< p` segments.
//!
//! The lineage set also maintains the block → (lineage, segment) index the
//! engine uses to route unlearning requests, and the per-placement sample
//! counts that shrink as data is removed (so RSN never counts samples that
//! were already forgotten).

use std::collections::BTreeMap;

use crate::data::dataset::{BlockId, UserId};
use crate::partition::Placement;

/// One block's placement inside a segment, with its *current* sample count
/// (decreases as unlearning requests remove data).
#[derive(Clone, Debug)]
pub struct SegPlacement {
    pub block: BlockId,
    pub user: UserId,
    pub samples: u64,
}

/// One round's worth of data added to a lineage.
#[derive(Clone, Debug)]
pub struct Segment {
    /// Round at which this data was learned (1-based).
    pub round: u32,
    pub placements: Vec<SegPlacement>,
}

impl Segment {
    pub fn samples(&self) -> u64 {
        self.placements.iter().map(|p| p.samples).sum()
    }
}

/// Where a block's data lives: lineage + segment index within it.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct SegmentRef {
    pub lineage: usize,
    pub segment: usize,
}

/// One shard's training history.
#[derive(Clone, Debug, Default)]
pub struct Lineage {
    pub segments: Vec<Segment>,
}

impl Lineage {
    /// Samples that must be replayed when retraining from a checkpoint
    /// covering `covered` segments (i.e. segments `covered..`).
    pub fn replay_samples(&self, covered: u32) -> u64 {
        self.segments
            .iter()
            .skip(covered as usize)
            .map(|s| s.samples())
            .sum()
    }

    /// Current total samples.
    pub fn total_samples(&self) -> u64 {
        self.replay_samples(0)
    }

    pub fn segment_count(&self) -> u32 {
        self.segments.len() as u32
    }

    /// The replay data (block, samples) from segment `covered` onward.
    pub fn replay_blocks(&self, covered: u32) -> Vec<(BlockId, u64)> {
        self.replay_range(covered, self.segment_count())
    }

    /// Replay data for segments `covered..through` (exclusive upper bound).
    ///
    /// This is the paper's retraining window: from the newest surviving
    /// checkpoint up to (and including) the poisoned segment — later
    /// sub-model versions are left in place (see DESIGN.md §Key-decisions
    /// on the paper's retraining accounting).
    pub fn replay_range(&self, covered: u32, through: u32) -> Vec<(BlockId, u64)> {
        self.segments
            .iter()
            .take(through as usize)
            .skip(covered as usize)
            .flat_map(|s| s.placements.iter())
            .filter(|p| p.samples > 0)
            .map(|p| (p.block, p.samples))
            .collect()
    }

    /// Samples in segments `covered..through`.
    pub fn replay_range_samples(&self, covered: u32, through: u32) -> u64 {
        self.segments
            .iter()
            .take(through as usize)
            .skip(covered as usize)
            .map(|s| s.samples())
            .sum()
    }
}

/// All lineages plus the block placement index.
#[derive(Clone, Debug)]
pub struct LineageSet {
    lineages: Vec<Lineage>,
    /// block -> all its placements (class-based partitioning splits blocks).
    index: BTreeMap<BlockId, Vec<SegmentRef>>,
}

impl LineageSet {
    pub fn new(max_shards: usize) -> Self {
        Self { lineages: vec![Lineage::default(); max_shards], index: BTreeMap::new() }
    }

    pub fn len(&self) -> usize {
        self.lineages.len()
    }

    pub fn is_empty(&self) -> bool {
        self.lineages.is_empty()
    }

    pub fn get(&self, l: usize) -> &Lineage {
        &self.lineages[l]
    }

    /// Record one round's placements; returns the lineages that received
    /// data this round (and must be (re)trained + checkpointed).
    pub fn add_round(
        &mut self,
        round: u32,
        placements: &[Placement],
        user_of: impl Fn(BlockId) -> UserId,
    ) -> Vec<usize> {
        let mut touched: BTreeMap<usize, Vec<SegPlacement>> = BTreeMap::new();
        for p in placements {
            touched.entry(p.shard).or_default().push(SegPlacement {
                block: p.block,
                user: user_of(p.block),
                samples: p.samples,
            });
        }
        let mut out = Vec::with_capacity(touched.len());
        for (lineage, placs) in touched {
            let seg_idx = self.lineages[lineage].segments.len();
            for sp in &placs {
                self.index
                    .entry(sp.block)
                    .or_default()
                    .push(SegmentRef { lineage, segment: seg_idx });
            }
            self.lineages[lineage].segments.push(Segment { round, placements: placs });
            out.push(lineage);
        }
        out
    }

    /// All placements of a block.
    pub fn placements_of(&self, block: BlockId) -> &[SegmentRef] {
        self.index.get(&block).map(|v| v.as_slice()).unwrap_or(&[])
    }

    /// Remove `n` samples of `block` (distributed across its placements
    /// proportionally, largest-first for the remainder). Returns the
    /// affected (lineage, segment) pairs with the amount actually removed.
    pub fn remove_samples(&mut self, block: BlockId, n: u64) -> Vec<(SegmentRef, u64)> {
        let refs = self.index.get(&block).cloned().unwrap_or_default();
        if refs.is_empty() || n == 0 {
            return vec![];
        }
        // Current sizes of each placement of this block.
        let mut sizes: Vec<u64> = refs
            .iter()
            .map(|r| {
                self.lineages[r.lineage].segments[r.segment]
                    .placements
                    .iter()
                    .filter(|p| p.block == block)
                    .map(|p| p.samples)
                    .sum()
            })
            .collect();
        let total: u64 = sizes.iter().sum();
        let n = n.min(total);
        if n == 0 {
            return vec![];
        }
        // Proportional split, remainder to the largest placements.
        let mut take: Vec<u64> =
            sizes.iter().map(|s| (n as u128 * *s as u128 / total as u128) as u64).collect();
        let mut assigned: u64 = take.iter().sum();
        let mut order: Vec<usize> = (0..refs.len()).collect();
        order.sort_by_key(|i| std::cmp::Reverse(sizes[*i] - take[*i]));
        let mut oi = 0;
        while assigned < n {
            let i = order[oi % order.len()];
            if take[i] < sizes[i] {
                take[i] += 1;
                assigned += 1;
            }
            oi += 1;
        }
        // Apply.
        let mut out = Vec::new();
        for (i, r) in refs.iter().enumerate() {
            if take[i] == 0 {
                continue;
            }
            let mut left = take[i];
            for p in &mut self.lineages[r.lineage].segments[r.segment].placements {
                if p.block == block && left > 0 {
                    let cut = left.min(p.samples);
                    p.samples -= cut;
                    left -= cut;
                }
            }
            debug_assert_eq!(left, 0);
            out.push((*r, take[i]));
            sizes[i] -= take[i];
        }
        out
    }

    /// Total samples currently held across all lineages.
    pub fn total_samples(&self) -> u64 {
        self.lineages.iter().map(|l| l.total_samples()).sum()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::dataset::{BlockId, UserId};
    use crate::partition::Placement;

    fn place(block: u64, shard: usize, samples: u64) -> Placement {
        Placement { block: BlockId(block), shard, samples }
    }

    #[test]
    fn add_round_builds_segments_and_index() {
        let mut ls = LineageSet::new(3);
        let touched = ls.add_round(
            1,
            &[place(0, 0, 100), place(1, 0, 50), place(2, 2, 30)],
            |_| UserId(0),
        );
        assert_eq!(touched, vec![0, 2]);
        assert_eq!(ls.get(0).total_samples(), 150);
        assert_eq!(ls.get(1).total_samples(), 0);
        assert_eq!(ls.get(2).total_samples(), 30);
        assert_eq!(ls.placements_of(BlockId(0)).len(), 1);
    }

    #[test]
    fn replay_counts_suffix_segments() {
        let mut ls = LineageSet::new(1);
        ls.add_round(1, &[place(0, 0, 100)], |_| UserId(0));
        ls.add_round(2, &[place(1, 0, 40)], |_| UserId(0));
        ls.add_round(3, &[place(2, 0, 60)], |_| UserId(0));
        let l = ls.get(0);
        assert_eq!(l.segment_count(), 3);
        assert_eq!(l.replay_samples(0), 200);
        assert_eq!(l.replay_samples(1), 100);
        assert_eq!(l.replay_samples(3), 0);
        assert_eq!(l.replay_blocks(1), vec![(BlockId(1), 40), (BlockId(2), 60)]);
    }

    #[test]
    fn remove_samples_shrinks_and_reports() {
        let mut ls = LineageSet::new(1);
        ls.add_round(1, &[place(0, 0, 100)], |_| UserId(0));
        let removed = ls.remove_samples(BlockId(0), 30);
        assert_eq!(removed.len(), 1);
        assert_eq!(removed[0].1, 30);
        assert_eq!(ls.get(0).total_samples(), 70);
        // Removing more than remains clamps.
        let removed = ls.remove_samples(BlockId(0), 1000);
        assert_eq!(removed[0].1, 70);
        assert_eq!(ls.get(0).total_samples(), 0);
        // Unknown block: no-op.
        assert!(ls.remove_samples(BlockId(9), 5).is_empty());
    }

    #[test]
    fn split_blocks_remove_proportionally() {
        let mut ls = LineageSet::new(2);
        // Class-based style: block 0 split 80/20 across two shards.
        ls.add_round(1, &[place(0, 0, 80), place(0, 1, 20)], |_| UserId(0));
        let removed = ls.remove_samples(BlockId(0), 50);
        let total_removed: u64 = removed.iter().map(|(_, n)| n).sum();
        assert_eq!(total_removed, 50);
        // Proportional-ish: shard 0 loses ~40, shard 1 ~10.
        let by_lineage: std::collections::BTreeMap<usize, u64> =
            removed.iter().map(|(r, n)| (r.lineage, *n)).collect();
        assert!(by_lineage[&0] >= 35 && by_lineage[&0] <= 45, "{by_lineage:?}");
        assert_eq!(ls.total_samples(), 50);
    }

    #[test]
    fn prop_removal_conserves_totals() {
        use crate::testkit::forall;
        forall(
            0x11EA6E,
            100,
            |rng, size| {
                let blocks = 1 + (10.0 * size) as usize;
                let shards = rng.range(1, 5);
                let placements: Vec<(u64, usize, u64)> = (0..blocks)
                    .map(|b| (b as u64, rng.range(0, shards), rng.range(1, 200) as u64))
                    .collect();
                let removals: Vec<(u64, u64)> = (0..blocks * 2)
                    .map(|_| {
                        (rng.range(0, blocks) as u64, rng.range(0, 300) as u64)
                    })
                    .collect();
                (shards, placements, removals)
            },
            |(shards, placements, removals)| {
                let mut ls = LineageSet::new(*shards);
                let ps: Vec<Placement> =
                    placements.iter().map(|(b, s, n)| place(*b, *s, *n)).collect();
                ls.add_round(1, &ps, |_| UserId(0));
                let mut expected: i64 = placements.iter().map(|(_, _, n)| *n as i64).sum();
                for (b, n) in removals {
                    let removed: u64 =
                        ls.remove_samples(BlockId(*b), *n).iter().map(|(_, k)| k).sum();
                    expected -= removed as i64;
                    if ls.total_samples() as i64 != expected {
                        return Err(format!(
                            "total {} != expected {expected}",
                            ls.total_samples()
                        ));
                    }
                }
                Ok(())
            },
        );
    }
}
