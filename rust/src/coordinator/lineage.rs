//! Shard lineages: which sub-model learned which data, in what order.
//!
//! A *lineage* is one shard's training history — a sequence of segments,
//! one per round in which the shard received data. A checkpoint taken after
//! segment k covers segments `0..=k` (incremental training, the paper's
//! Fig. 1 semantics: M2 is M1 plus D2). Unlearning data that entered at
//! segment p invalidates every checkpoint covering p and restarts training
//! from the newest stored checkpoint covering `< p` segments.
//!
//! The lineage set also maintains the block → (lineage, segment, slot)
//! index the engine uses to route unlearning requests, and the
//! per-placement sample counts that shrink as data is removed (so RSN
//! never counts samples that were already forgotten).
//!
//! ## Complexity
//!
//! Sample totals are served from an incrementally maintained Fenwick tree
//! of per-segment counts plus a cached lineage total, so the planner's
//! pricing probes never walk segment lists:
//!
//! * [`Lineage::total_samples`] — O(1)
//! * [`Lineage::replay_samples`] / [`Lineage::replay_range_samples`] —
//!   O(log segments)
//! * [`LineageSet::remove_samples`] — O(placements of the block), via the
//!   slot index (no rescan of the segments' placement lists)
//!
//! [`Lineage::replay_blocks`] / [`Lineage::replay_range`] still materialize
//! the actual replay set — they are execution-path only. The property
//! tests below check every indexed quantity against a naive recomputation
//! from the segment lists.

use std::collections::BTreeMap;

use crate::data::dataset::{BlockId, UserId};
use crate::partition::Placement;

/// One block's placement inside a segment, with its *current* sample count
/// (decreases as unlearning requests remove data).
#[derive(Clone, Debug)]
pub struct SegPlacement {
    pub block: BlockId,
    pub user: UserId,
    pub samples: u64,
}

/// One round's worth of data added to a lineage.
#[derive(Clone, Debug)]
pub struct Segment {
    /// Round at which this data was learned (1-based).
    pub round: u32,
    pub placements: Vec<SegPlacement>,
}

impl Segment {
    pub fn samples(&self) -> u64 {
        self.placements.iter().map(|p| p.samples).sum()
    }
}

/// Where a block's data lives: lineage + segment index within it.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct SegmentRef {
    pub lineage: usize,
    pub segment: usize,
}

/// One placement of a block: its segment plus the slot it occupies in the
/// segment's placement list, so removal addresses it directly instead of
/// rescanning the list.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct PlacementSlot {
    pub seg: SegmentRef,
    /// Index into the segment's `placements`.
    pub slot: u32,
}

/// Fenwick (binary indexed) tree over per-segment sample counts: O(log n)
/// prefix sums and point decrements, append-only positions — exactly the
/// lineage lifecycle (segments are only ever appended; samples only ever
/// shrink).
#[derive(Clone, Debug, Default)]
struct Fenwick {
    /// 1-based implicit tree; `tree[i-1]` sums the `lowbit(i)` elements
    /// ending at position i.
    tree: Vec<u64>,
}

impl Fenwick {
    fn len(&self) -> usize {
        self.tree.len()
    }

    /// Append a new element holding `v`.
    fn push(&mut self, v: u64) {
        let idx = self.tree.len() + 1; // 1-based position of the new leaf
        let lowbit = idx & idx.wrapping_neg();
        // tree[idx] covers (idx - lowbit, idx]: the new value plus the
        // already-built subtrees directly below it.
        let mut val = v;
        let mut j = idx - 1;
        let stop = idx - lowbit;
        while j > stop {
            val += self.tree[j - 1];
            j -= j & j.wrapping_neg();
        }
        self.tree.push(val);
    }

    /// Subtract `amount` from the element at 0-based `pos` (counts only
    /// ever shrink, so no signed arithmetic is needed).
    fn sub(&mut self, pos: usize, amount: u64) {
        let mut i = pos + 1;
        while i <= self.tree.len() {
            self.tree[i - 1] -= amount;
            i += i & i.wrapping_neg();
        }
    }

    /// Sum of the first `n` elements (clamped to the current length).
    fn prefix(&self, n: usize) -> u64 {
        let mut i = n.min(self.tree.len());
        let mut s = 0;
        while i > 0 {
            s += self.tree[i - 1];
            i -= i & i.wrapping_neg();
        }
        s
    }
}

/// One shard's training history.
#[derive(Clone, Debug, Default)]
pub struct Lineage {
    segments: Vec<Segment>,
    /// Current per-segment sample counts, prefix-summable in O(log n).
    seg_totals: Fenwick,
    /// Cached sum over all segments (kept in lockstep with `seg_totals`).
    total: u64,
}

impl Lineage {
    /// The segment history (read-only; all mutation goes through
    /// [`LineageSet`] so the prefix sums stay consistent).
    pub fn segments(&self) -> &[Segment] {
        &self.segments
    }

    /// Samples that must be replayed when retraining from a checkpoint
    /// covering `covered` segments (i.e. segments `covered..`).
    /// O(log segments).
    pub fn replay_samples(&self, covered: u32) -> u64 {
        self.total - self.seg_totals.prefix(covered as usize)
    }

    /// Current total samples. O(1).
    pub fn total_samples(&self) -> u64 {
        self.total
    }

    pub fn segment_count(&self) -> u32 {
        self.segments.len() as u32
    }

    /// The replay data (block, samples) from segment `covered` onward.
    pub fn replay_blocks(&self, covered: u32) -> Vec<(BlockId, u64)> {
        self.replay_range(covered, self.segment_count())
    }

    /// Replay data for segments `covered..through` (exclusive upper bound).
    ///
    /// This is the paper's retraining window: from the newest surviving
    /// checkpoint up to (and including) the poisoned segment — later
    /// sub-model versions are left in place (see DESIGN.md §Key-decisions
    /// on the paper's retraining accounting). Materializes the replay set;
    /// execution-path only — cost probes use
    /// [`Lineage::replay_range_samples`].
    pub fn replay_range(&self, covered: u32, through: u32) -> Vec<(BlockId, u64)> {
        self.segments
            .iter()
            .take(through as usize)
            .skip(covered as usize)
            .flat_map(|s| s.placements.iter())
            .filter(|p| p.samples > 0)
            .map(|p| (p.block, p.samples))
            .collect()
    }

    /// Samples in segments `covered..through`. O(log segments).
    pub fn replay_range_samples(&self, covered: u32, through: u32) -> u64 {
        self.seg_totals
            .prefix(through as usize)
            .saturating_sub(self.seg_totals.prefix(covered as usize))
    }
}

/// All lineages plus the block placement index.
#[derive(Clone, Debug)]
pub struct LineageSet {
    lineages: Vec<Lineage>,
    /// block -> all its placements (class-based partitioning splits
    /// blocks), with the slot each occupies in its segment. Placements of
    /// one block within the same segment are pushed consecutively by
    /// `add_round` (a block is placed in exactly one round), which
    /// `remove_samples` relies on when grouping.
    index: BTreeMap<BlockId, Vec<PlacementSlot>>,
}

impl LineageSet {
    pub fn new(max_shards: usize) -> Self {
        Self { lineages: vec![Lineage::default(); max_shards], index: BTreeMap::new() }
    }

    pub fn len(&self) -> usize {
        self.lineages.len()
    }

    pub fn is_empty(&self) -> bool {
        self.lineages.is_empty()
    }

    pub fn get(&self, l: usize) -> &Lineage {
        &self.lineages[l]
    }

    /// Record one round's placements; returns the lineages that received
    /// data this round (and must be (re)trained + checkpointed).
    pub fn add_round(
        &mut self,
        round: u32,
        placements: &[Placement],
        user_of: impl Fn(BlockId) -> UserId,
    ) -> Vec<usize> {
        let mut touched: BTreeMap<usize, Vec<SegPlacement>> = BTreeMap::new();
        for p in placements {
            touched.entry(p.shard).or_default().push(SegPlacement {
                block: p.block,
                user: user_of(p.block),
                samples: p.samples,
            });
        }
        let mut out = Vec::with_capacity(touched.len());
        for (lineage, placs) in touched {
            let seg_idx = self.lineages[lineage].segments.len();
            for (slot, sp) in placs.iter().enumerate() {
                self.index.entry(sp.block).or_default().push(PlacementSlot {
                    seg: SegmentRef { lineage, segment: seg_idx },
                    slot: slot as u32,
                });
            }
            let seg = Segment { round, placements: placs };
            let seg_samples = seg.samples();
            let l = &mut self.lineages[lineage];
            l.segments.push(seg);
            l.seg_totals.push(seg_samples);
            l.total += seg_samples;
            debug_assert_eq!(l.seg_totals.len(), l.segments.len());
            out.push(lineage);
        }
        out
    }

    /// All placements of a block.
    pub fn placements_of(&self, block: BlockId) -> &[PlacementSlot] {
        self.index.get(&block).map(|v| v.as_slice()).unwrap_or(&[])
    }

    /// Remove `n` samples of `block` (distributed across its placements
    /// proportionally, largest-first for the remainder). Returns the
    /// affected (lineage, segment) pairs with the amount actually removed.
    ///
    /// Each placement entry reports its whole segment's holding of the
    /// block as its size — the pre-index scan semantics, preserved exactly;
    /// the slot index only replaces the placement-list rescans with direct
    /// loads and keeps the prefix sums in lockstep.
    pub fn remove_samples(&mut self, block: BlockId, n: u64) -> Vec<(SegmentRef, u64)> {
        let refs = self.index.get(&block).cloned().unwrap_or_default();
        if refs.is_empty() || n == 0 {
            return vec![];
        }
        // Current size of each placement group of this block: consecutive
        // entries sharing a segment report that segment's combined count.
        let mut sizes: Vec<u64> = Vec::with_capacity(refs.len());
        let mut i = 0;
        while i < refs.len() {
            let seg = refs[i].seg;
            let mut j = i;
            while j < refs.len() && refs[j].seg == seg {
                j += 1;
            }
            let placements = &self.lineages[seg.lineage].segments[seg.segment].placements;
            let sum: u64 = refs[i..j].iter().map(|r| placements[r.slot as usize].samples).sum();
            sizes.resize(j, sum);
            i = j;
        }
        let total: u64 = sizes.iter().sum();
        let n = n.min(total);
        if n == 0 {
            return vec![];
        }
        // Proportional split, remainder to the largest placements.
        let mut take: Vec<u64> =
            sizes.iter().map(|s| (n as u128 * *s as u128 / total as u128) as u64).collect();
        let mut assigned: u64 = take.iter().sum();
        let mut order: Vec<usize> = (0..refs.len()).collect();
        order.sort_by_key(|i| std::cmp::Reverse(sizes[*i] - take[*i]));
        let mut oi = 0;
        while assigned < n {
            let i = order[oi % order.len()];
            if take[i] < sizes[i] {
                take[i] += 1;
                assigned += 1;
            }
            oi += 1;
        }
        // Apply: consume each entry's share from the block's slots of its
        // segment in slot order (identical to the old placement-list walk).
        let mut out = Vec::new();
        let mut i = 0;
        while i < refs.len() {
            let seg = refs[i].seg;
            let mut j = i;
            while j < refs.len() && refs[j].seg == seg {
                j += 1;
            }
            for k in i..j {
                if take[k] == 0 {
                    continue;
                }
                let mut left = take[k];
                let l = &mut self.lineages[seg.lineage];
                for r in &refs[i..j] {
                    if left == 0 {
                        break;
                    }
                    let p = &mut l.segments[seg.segment].placements[r.slot as usize];
                    let cut = left.min(p.samples);
                    p.samples -= cut;
                    left -= cut;
                }
                debug_assert_eq!(left, 0);
                l.seg_totals.sub(seg.segment, take[k]);
                l.total -= take[k];
                out.push((seg, take[k]));
            }
            i = j;
        }
        out
    }

    /// Total samples currently held across all lineages.
    pub fn total_samples(&self) -> u64 {
        self.lineages.iter().map(|l| l.total_samples()).sum()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::dataset::{BlockId, UserId};
    use crate::partition::Placement;

    fn place(block: u64, shard: usize, samples: u64) -> Placement {
        Placement { block: BlockId(block), shard, samples }
    }

    /// Naive recomputation of `replay_samples` from the segment lists.
    fn scan_replay(l: &Lineage, covered: u32) -> u64 {
        l.segments().iter().skip(covered as usize).map(|s| s.samples()).sum()
    }

    /// Naive recomputation of `replay_range_samples`.
    fn scan_range(l: &Lineage, covered: u32, through: u32) -> u64 {
        l.segments()
            .iter()
            .take(through as usize)
            .skip(covered as usize)
            .map(|s| s.samples())
            .sum()
    }

    #[test]
    fn add_round_builds_segments_and_index() {
        let mut ls = LineageSet::new(3);
        let touched = ls.add_round(
            1,
            &[place(0, 0, 100), place(1, 0, 50), place(2, 2, 30)],
            |_| UserId(0),
        );
        assert_eq!(touched, vec![0, 2]);
        assert_eq!(ls.get(0).total_samples(), 150);
        assert_eq!(ls.get(1).total_samples(), 0);
        assert_eq!(ls.get(2).total_samples(), 30);
        assert_eq!(ls.placements_of(BlockId(0)).len(), 1);
        assert_eq!(ls.placements_of(BlockId(1))[0].slot, 1);
    }

    #[test]
    fn replay_counts_suffix_segments() {
        let mut ls = LineageSet::new(1);
        ls.add_round(1, &[place(0, 0, 100)], |_| UserId(0));
        ls.add_round(2, &[place(1, 0, 40)], |_| UserId(0));
        ls.add_round(3, &[place(2, 0, 60)], |_| UserId(0));
        let l = ls.get(0);
        assert_eq!(l.segment_count(), 3);
        assert_eq!(l.replay_samples(0), 200);
        assert_eq!(l.replay_samples(1), 100);
        assert_eq!(l.replay_samples(3), 0);
        assert_eq!(l.replay_blocks(1), vec![(BlockId(1), 40), (BlockId(2), 60)]);
        // Range queries, including degenerate and out-of-range bounds.
        assert_eq!(l.replay_range_samples(0, 3), 200);
        assert_eq!(l.replay_range_samples(1, 2), 40);
        assert_eq!(l.replay_range_samples(2, 2), 0);
        assert_eq!(l.replay_range_samples(3, 1), 0);
        assert_eq!(l.replay_range_samples(1, 99), 100);
    }

    #[test]
    fn remove_samples_shrinks_and_reports() {
        let mut ls = LineageSet::new(1);
        ls.add_round(1, &[place(0, 0, 100)], |_| UserId(0));
        let removed = ls.remove_samples(BlockId(0), 30);
        assert_eq!(removed.len(), 1);
        assert_eq!(removed[0].1, 30);
        assert_eq!(ls.get(0).total_samples(), 70);
        // Removing more than remains clamps.
        let removed = ls.remove_samples(BlockId(0), 1000);
        assert_eq!(removed[0].1, 70);
        assert_eq!(ls.get(0).total_samples(), 0);
        // Unknown block: no-op.
        assert!(ls.remove_samples(BlockId(9), 5).is_empty());
    }

    #[test]
    fn split_blocks_remove_proportionally() {
        let mut ls = LineageSet::new(2);
        // Class-based style: block 0 split 80/20 across two shards.
        ls.add_round(1, &[place(0, 0, 80), place(0, 1, 20)], |_| UserId(0));
        let removed = ls.remove_samples(BlockId(0), 50);
        let total_removed: u64 = removed.iter().map(|(_, n)| n).sum();
        assert_eq!(total_removed, 50);
        // Proportional-ish: shard 0 loses ~40, shard 1 ~10.
        let by_lineage: std::collections::BTreeMap<usize, u64> =
            removed.iter().map(|(r, n)| (r.lineage, *n)).collect();
        assert!(by_lineage[&0] >= 35 && by_lineage[&0] <= 45, "{by_lineage:?}");
        assert_eq!(ls.total_samples(), 50);
    }

    #[test]
    fn prop_removal_conserves_totals() {
        use crate::testkit::forall;
        forall(
            0x11EA6E,
            100,
            |rng, size| {
                let blocks = 1 + (10.0 * size) as usize;
                let shards = rng.range(1, 5);
                let placements: Vec<(u64, usize, u64)> = (0..blocks)
                    .map(|b| (b as u64, rng.range(0, shards), rng.range(1, 200) as u64))
                    .collect();
                let removals: Vec<(u64, u64)> = (0..blocks * 2)
                    .map(|_| {
                        (rng.range(0, blocks) as u64, rng.range(0, 300) as u64)
                    })
                    .collect();
                (shards, placements, removals)
            },
            |(shards, placements, removals)| {
                let mut ls = LineageSet::new(*shards);
                let ps: Vec<Placement> =
                    placements.iter().map(|(b, s, n)| place(*b, *s, *n)).collect();
                ls.add_round(1, &ps, |_| UserId(0));
                let mut expected: i64 = placements.iter().map(|(_, _, n)| *n as i64).sum();
                for (b, n) in removals {
                    let removed: u64 =
                        ls.remove_samples(BlockId(*b), *n).iter().map(|(_, k)| k).sum();
                    expected -= removed as i64;
                    if ls.total_samples() as i64 != expected {
                        return Err(format!(
                            "total {} != expected {expected}",
                            ls.total_samples()
                        ));
                    }
                }
                Ok(())
            },
        );
    }

    /// The indexed quantities (cached totals, Fenwick prefix sums) must
    /// agree with a naive recomputation from the segment lists after any
    /// interleaving of multi-round adds and removals.
    #[test]
    fn prop_prefix_sums_match_scan_under_interleaving() {
        use crate::testkit::forall;
        forall(
            0xFE2C1C,
            80,
            |rng, size| {
                let shards = rng.range(1, 4);
                let rounds = 1 + (6.0 * size) as usize;
                let mut next_block = 0u64;
                // Per round: the new blocks placed, then some removals of
                // any block placed so far.
                let mut script: Vec<(Vec<(u64, usize, u64)>, Vec<(u64, u64)>)> = Vec::new();
                for _ in 0..rounds {
                    let adds: Vec<(u64, usize, u64)> = (0..rng.range(1, 5))
                        .map(|_| {
                            let b = next_block;
                            next_block += 1;
                            (b, rng.range(0, shards), rng.range(1, 120) as u64)
                        })
                        .collect();
                    let removals: Vec<(u64, u64)> = (0..rng.range(0, 4))
                        .map(|_| {
                            (rng.range(0, next_block as usize) as u64,
                             rng.range(0, 200) as u64)
                        })
                        .collect();
                    script.push((adds, removals));
                }
                (shards, script)
            },
            |(shards, script)| {
                let mut ls = LineageSet::new(*shards);
                let check = |ls: &LineageSet| -> Result<(), String> {
                    for li in 0..ls.len() {
                        let l = ls.get(li);
                        if l.total_samples() != scan_replay(l, 0) {
                            return Err(format!("lineage {li}: cached total diverged"));
                        }
                        let n = l.segment_count();
                        for c in 0..=n + 1 {
                            if l.replay_samples(c) != scan_replay(l, c) {
                                return Err(format!(
                                    "lineage {li}: replay_samples({c}) diverged"
                                ));
                            }
                            for t in c..=n + 1 {
                                if l.replay_range_samples(c, t) != scan_range(l, c, t) {
                                    return Err(format!(
                                        "lineage {li}: replay_range_samples({c},{t}) diverged"
                                    ));
                                }
                            }
                        }
                    }
                    Ok(())
                };
                for (round, (adds, removals)) in script.iter().enumerate() {
                    let ps: Vec<Placement> =
                        adds.iter().map(|(b, s, n)| place(*b, *s, *n)).collect();
                    ls.add_round(round as u32 + 1, &ps, |_| UserId(0));
                    check(&ls)?;
                    for (b, n) in removals {
                        ls.remove_samples(BlockId(*b), *n);
                        check(&ls)?;
                    }
                }
                Ok(())
            },
        );
    }
}
