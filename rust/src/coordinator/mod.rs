//! The Layer-3 coordinator: shard lineages, the unlearning engine, system
//! presets (CAUSE and all baselines), and result aggregation.

pub mod aggregate;
pub mod engine;
pub mod lineage;
pub mod system;

pub use engine::{Engine, ExecMode, NaivePlanResolution, RoundReport, UnlearnOutcome};
pub use lineage::{Lineage, LineageSet, PlacementSlot, SegmentRef};
pub use system::{CauseSystem, SystemVariant};
