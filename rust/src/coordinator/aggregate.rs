//! Result aggregation: label-based majority vote over sub-models
//! (the paper's aggregation strategy, same as SISA/ARCANE).

/// Majority vote over per-model predicted labels for one example.
/// Ties break toward the lowest label (deterministic).
pub fn majority_vote(predictions: &[usize], classes: usize) -> usize {
    let mut counts = vec![0u32; classes];
    for &p in predictions {
        if p < classes {
            counts[p] += 1;
        }
    }
    counts
        .iter()
        .enumerate()
        .max_by_key(|(label, c)| (**c, std::cmp::Reverse(*label)))
        .map(|(label, _)| label)
        .unwrap_or(0)
}

/// Argmax of one logits row.
pub fn argmax(logits: &[f32]) -> usize {
    let mut best = 0;
    for (i, v) in logits.iter().enumerate() {
        if *v > logits[best] {
            best = i;
        }
    }
    best
}

/// Ensemble accuracy: per-model logits (model × example × class collapsed
/// to labels), majority-voted against ground truth.
pub fn ensemble_accuracy(
    per_model_labels: &[Vec<usize>],
    truth: &[f32],
    classes: usize,
) -> f64 {
    if per_model_labels.is_empty() || truth.is_empty() {
        return 0.0;
    }
    let n = truth.len();
    let mut correct = 0usize;
    let mut votes = Vec::with_capacity(per_model_labels.len());
    for i in 0..n {
        votes.clear();
        for m in per_model_labels {
            votes.push(m[i]);
        }
        if majority_vote(&votes, classes) == truth[i] as usize {
            correct += 1;
        }
    }
    correct as f64 / n as f64
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn majority_picks_mode() {
        assert_eq!(majority_vote(&[1, 1, 2], 3), 1);
        assert_eq!(majority_vote(&[0, 2, 2, 2], 3), 2);
    }

    #[test]
    fn tie_breaks_low() {
        assert_eq!(majority_vote(&[0, 1], 2), 0);
        assert_eq!(majority_vote(&[2, 1], 3), 1);
    }

    #[test]
    fn argmax_first_max_wins() {
        assert_eq!(argmax(&[0.1, 0.9, 0.9]), 1);
        assert_eq!(argmax(&[3.0]), 0);
    }

    #[test]
    fn ensemble_accuracy_counts() {
        // Two models; model 0 is right on both, model 1 wrong on second.
        let labels = vec![vec![0, 1], vec![0, 0]];
        let acc = ensemble_accuracy(&labels, &[0.0, 1.0], 2);
        // Example 0: votes {0,0} -> 0 correct. Example 1: {1,0} tie -> 0, wrong.
        assert!((acc - 0.5).abs() < 1e-12);
    }

    #[test]
    fn out_of_range_votes_ignored() {
        assert_eq!(majority_vote(&[9, 9, 1], 3), 1);
    }
}
