//! Run metrics: everything the paper reports, accumulated per round, plus
//! the service-level latency receipts the deadline-aware batch scheduler
//! is judged by (queueing delay vs retrains coalesced).

use crate::load::LatencyHistogram;
use crate::util::Summary;

/// Receipts kept verbatim in [`RunMetrics::latency`]; past this the Vec
/// stops growing and further receipts land only in the histogram (plus
/// the `latency_dropped` counter), so an open-loop soak can run for
/// millions of requests without unbounded memory. Far above anything a
/// test or bench produces, so capped and uncapped runs are byte-equal
/// everywhere that matters.
pub const LATENCY_RECEIPT_CAP: usize = 1 << 16;

/// Per-request latency receipt stamped by the unlearning service when the
/// request's batch window executes. `queued_ticks` is the service-clock
/// delay between arrival and service; `slo_met` records whether the
/// configured deadline policy honored its bound (always true for policies
/// that promise none).
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct LatencyReceipt {
    pub user: u32,
    /// Round the request targeted (trace bookkeeping, not the serve time).
    pub round: u32,
    pub queued_ticks: u64,
    pub slo_met: bool,
}

/// Metrics for one system run over a trace.
#[derive(Clone, Debug, Default)]
pub struct RunMetrics {
    /// Retrained-sample number per round (the paper's RSN).
    pub rsn_by_round: Vec<u64>,
    /// Unlearning requests served per round.
    pub requests_by_round: Vec<u64>,
    /// Retrains started from a stored checkpoint vs from scratch.
    pub warm_retrains: u64,
    pub scratch_retrains: u64,
    /// Lineages retrained in total (a request can touch several).
    pub lineages_retrained: u64,
    /// Energy consumed by unlearning work, joules.
    pub energy_joules: f64,
    /// Pruning passes executed.
    pub prunes: u64,
    /// Store events.
    pub ckpts_stored: u64,
    pub ckpts_replaced: u64,
    pub ckpts_rejected: u64,
    pub ckpts_invalidated: u64,
    /// Batched-service counters: drain windows executed and the requests
    /// they served (zero when the engine is driven strictly FCFS).
    pub batches: u64,
    pub batched_requests: u64,
    /// Per-request lineage retrains avoided by coalescing: a lineage
    /// poisoned by k requests in one window retrains once, saving k-1.
    pub retrains_coalesced: u64,
    /// Per-request queueing-delay receipts (service drains only; empty
    /// when the engine is driven directly). Bounded by
    /// [`LATENCY_RECEIPT_CAP`]; the histogram below keeps the full
    /// distribution regardless.
    pub latency: Vec<LatencyReceipt>,
    /// Every receipt's queueing delay, log-bucketed — never dropped,
    /// mergeable across shards, and what the obs registry exports.
    pub latency_hist: LatencyHistogram,
    /// Receipts not retained in `latency` because the cap was hit.
    pub latency_dropped: u64,
    /// SLO misses counted at record time (receipts past the cap still
    /// count, unlike a scan of the truncated Vec).
    pub latency_slo_miss: u64,
    /// Ensemble accuracy per evaluation point (only with a real trainer).
    pub accuracy_by_round: Vec<Option<f64>>,
}

impl RunMetrics {
    /// Account `served` requests totalling `rsn` replayed samples into the
    /// current round slot. Requests served before any training round open
    /// a round-0 slot instead of silently vanishing (the engine previously
    /// dropped both the RSN and the request count in that case).
    pub fn record_requests(&mut self, served: u64, rsn: u64) {
        if self.rsn_by_round.is_empty() {
            self.rsn_by_round.push(0);
        }
        if self.requests_by_round.is_empty() {
            self.requests_by_round.push(0);
        }
        *self.rsn_by_round.last_mut().expect("slot just ensured") += rsn;
        *self.requests_by_round.last_mut().expect("slot just ensured") += served;
    }

    /// Record one served request's queueing-delay receipt: always into
    /// the histogram and the SLO-miss counter, verbatim into `latency`
    /// only while under [`LATENCY_RECEIPT_CAP`].
    pub fn record_latency(&mut self, receipt: LatencyReceipt) {
        self.latency_hist.record(receipt.queued_ticks);
        if !receipt.slo_met {
            self.latency_slo_miss += 1;
        }
        if self.latency.len() < LATENCY_RECEIPT_CAP {
            self.latency.push(receipt);
        } else {
            self.latency_dropped += 1;
        }
    }

    /// Distribution of queueing delays (ticks) across latency receipts.
    pub fn queue_delay_summary(&self) -> Summary {
        let delays: Vec<f64> =
            self.latency.iter().map(|r| r.queued_ticks as f64).collect();
        Summary::of(&delays)
    }

    /// Requests served past their latency SLO. Counted at record time,
    /// so receipts dropped past the retention cap still count.
    pub fn slo_violations(&self) -> u64 {
        self.latency_slo_miss
    }

    pub fn total_rsn(&self) -> u64 {
        self.rsn_by_round.iter().sum()
    }

    pub fn total_requests(&self) -> u64 {
        self.requests_by_round.iter().sum()
    }

    pub fn final_accuracy(&self) -> Option<f64> {
        self.accuracy_by_round.iter().rev().flatten().next().copied()
    }

    /// Cumulative RSN after each round (Fig. 11's series).
    pub fn cumulative_rsn(&self) -> Vec<u64> {
        let mut acc = 0;
        self.rsn_by_round
            .iter()
            .map(|r| {
                acc += r;
                acc
            })
            .collect()
    }

    /// Merge per-shard run metrics into one fleet-level view, in shard
    /// order (deterministic given the routing seed). Counters sum;
    /// per-round series sum elementwise (shorter series are treated as
    /// zero-padded); latency receipts concatenate shard-by-shard;
    /// accuracy per round is the mean of the shards that measured one.
    /// Aggregating a single shard is the identity.
    pub fn fleet_aggregate(shards: &[RunMetrics]) -> RunMetrics {
        if shards.len() == 1 {
            return shards[0].clone();
        }
        let mut out = RunMetrics::default();
        for m in shards {
            for (i, v) in m.rsn_by_round.iter().enumerate() {
                if out.rsn_by_round.len() <= i {
                    out.rsn_by_round.push(0);
                }
                out.rsn_by_round[i] += v;
            }
            for (i, v) in m.requests_by_round.iter().enumerate() {
                if out.requests_by_round.len() <= i {
                    out.requests_by_round.push(0);
                }
                out.requests_by_round[i] += v;
            }
            out.warm_retrains += m.warm_retrains;
            out.scratch_retrains += m.scratch_retrains;
            out.lineages_retrained += m.lineages_retrained;
            out.energy_joules += m.energy_joules;
            out.prunes += m.prunes;
            out.ckpts_stored += m.ckpts_stored;
            out.ckpts_replaced += m.ckpts_replaced;
            out.ckpts_rejected += m.ckpts_rejected;
            out.ckpts_invalidated += m.ckpts_invalidated;
            out.batches += m.batches;
            out.batched_requests += m.batched_requests;
            out.retrains_coalesced += m.retrains_coalesced;
            out.latency.extend(m.latency.iter().cloned());
            out.latency_hist.merge(&m.latency_hist);
            out.latency_dropped += m.latency_dropped;
            out.latency_slo_miss += m.latency_slo_miss;
        }
        let acc_rounds = shards.iter().map(|m| m.accuracy_by_round.len()).max().unwrap_or(0);
        for i in 0..acc_rounds {
            let measured: Vec<f64> = shards
                .iter()
                .filter_map(|m| m.accuracy_by_round.get(i).copied().flatten())
                .collect();
            out.accuracy_by_round.push(if measured.is_empty() {
                None
            } else {
                Some(measured.iter().sum::<f64>() / measured.len() as f64)
            });
        }
        out
    }

    pub fn to_json(&self) -> crate::util::Json {
        use crate::util::Json;
        let delays = self.queue_delay_summary();
        Json::obj()
            .set("rsn_by_round", self.rsn_by_round.clone())
            .set("total_rsn", self.total_rsn())
            .set("requests", self.total_requests())
            .set("warm_retrains", self.warm_retrains)
            .set("scratch_retrains", self.scratch_retrains)
            .set("lineages_retrained", self.lineages_retrained)
            .set("energy_joules", self.energy_joules)
            .set("prunes", self.prunes)
            .set("ckpts_stored", self.ckpts_stored)
            .set("ckpts_replaced", self.ckpts_replaced)
            .set("ckpts_rejected", self.ckpts_rejected)
            .set("ckpts_invalidated", self.ckpts_invalidated)
            .set("batches", self.batches)
            .set("batched_requests", self.batched_requests)
            .set("retrains_coalesced", self.retrains_coalesced)
            .set("queue_delay_p50", delays.p50)
            .set("queue_delay_p99", delays.p99)
            .set("slo_violations", self.slo_violations())
            .set("latency_receipts", self.latency.len() as u64 + self.latency_dropped)
            .set("latency_dropped", self.latency_dropped)
            .set(
                "accuracy_by_round",
                Json::Arr(
                    self.accuracy_by_round
                        .iter()
                        .map(|a| a.map(Json::Num).unwrap_or(Json::Null))
                        .collect(),
                ),
            )
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn cumulative_and_totals() {
        let m = RunMetrics {
            rsn_by_round: vec![10, 20, 30],
            requests_by_round: vec![1, 2, 3],
            ..Default::default()
        };
        assert_eq!(m.total_rsn(), 60);
        assert_eq!(m.cumulative_rsn(), vec![10, 30, 60]);
        assert_eq!(m.total_requests(), 6);
    }

    #[test]
    fn final_accuracy_skips_missing() {
        let m = RunMetrics {
            accuracy_by_round: vec![Some(0.5), None, Some(0.7), None],
            ..Default::default()
        };
        assert_eq!(m.final_accuracy(), Some(0.7));
        assert_eq!(RunMetrics::default().final_accuracy(), None);
    }

    #[test]
    fn json_has_key_fields() {
        let s = RunMetrics::default().to_json().to_string();
        assert!(s.contains("total_rsn"));
        assert!(s.contains("energy_joules"));
        assert!(s.contains("retrains_coalesced"));
        assert!(s.contains("queue_delay_p99"));
        assert!(s.contains("slo_violations"));
    }

    #[test]
    fn latency_receipts_aggregate() {
        let mut m = RunMetrics::default();
        assert_eq!(m.queue_delay_summary().n, 0);
        for (q, met) in [(0u64, true), (2, true), (4, false), (4, false)] {
            m.record_latency(LatencyReceipt {
                user: 1,
                round: 1,
                queued_ticks: q,
                slo_met: met,
            });
        }
        let s = m.queue_delay_summary();
        assert_eq!(s.n, 4);
        assert_eq!(s.max, 4.0);
        assert!(s.p50 <= s.p99);
        assert_eq!(m.slo_violations(), 2);
    }

    #[test]
    fn latency_cap_folds_into_histogram() {
        let mut m = RunMetrics::default();
        let n = LATENCY_RECEIPT_CAP + 10;
        for i in 0..n {
            m.record_latency(LatencyReceipt {
                user: 0,
                round: 0,
                queued_ticks: i as u64 % 7,
                slo_met: i % 2 == 0,
            });
        }
        assert_eq!(m.latency.len(), LATENCY_RECEIPT_CAP, "Vec stops at the cap");
        assert_eq!(m.latency_dropped, 10);
        assert_eq!(m.latency_hist.count(), n as u64, "histogram never drops");
        assert_eq!(m.slo_violations(), (n / 2) as u64, "misses counted past the cap");
        let j = m.to_json();
        assert_eq!(j.at(&["latency_receipts"]).and_then(|v| v.as_u64()), Some(n as u64));
        assert_eq!(j.at(&["latency_dropped"]).and_then(|v| v.as_u64()), Some(10));
        // Fleet aggregation carries the counters and merges the histogram.
        let f = RunMetrics::fleet_aggregate(&[m.clone(), m.clone()]);
        assert_eq!(f.latency_dropped, 20);
        assert_eq!(f.latency_hist.count(), 2 * n as u64);
        assert_eq!(f.slo_violations(), 2 * (n / 2) as u64);
    }

    #[test]
    fn fleet_aggregate_sums_and_identity() {
        let a = RunMetrics {
            rsn_by_round: vec![10, 20],
            requests_by_round: vec![1, 2],
            batches: 3,
            energy_joules: 1.5,
            accuracy_by_round: vec![Some(0.25), None],
            latency: vec![LatencyReceipt { user: 1, round: 1, queued_ticks: 2, slo_met: true }],
            ..Default::default()
        };
        let b = RunMetrics {
            rsn_by_round: vec![5],
            requests_by_round: vec![4],
            batches: 1,
            energy_joules: 0.5,
            accuracy_by_round: vec![Some(0.75), Some(0.9)],
            ..Default::default()
        };
        let f = RunMetrics::fleet_aggregate(&[a.clone(), b]);
        assert_eq!(f.rsn_by_round, vec![15, 20]);
        assert_eq!(f.requests_by_round, vec![5, 2]);
        assert_eq!(f.batches, 4);
        assert!((f.energy_joules - 2.0).abs() < 1e-12);
        // Mean over shards that measured; pass-through when only one did.
        assert_eq!(f.accuracy_by_round, vec![Some(0.5), Some(0.9)]);
        assert_eq!(f.latency.len(), 1);
        // Single shard aggregates to itself.
        let id = RunMetrics::fleet_aggregate(&[a.clone()]);
        assert_eq!(id.rsn_by_round, a.rsn_by_round);
        assert_eq!(id.batches, a.batches);
        assert_eq!(id.latency, a.latency);
    }

    #[test]
    fn record_requests_opens_round0_slot() {
        let mut m = RunMetrics::default();
        // Request before any training round: must not vanish.
        m.record_requests(1, 0);
        assert_eq!(m.total_requests(), 1);
        assert_eq!(m.rsn_by_round.len(), 1);
        // Subsequent rounds append their own slots as usual.
        m.rsn_by_round.push(0);
        m.requests_by_round.push(0);
        m.record_requests(2, 70);
        assert_eq!(m.total_requests(), 3);
        assert_eq!(m.rsn_by_round, vec![0, 70]);
        assert_eq!(m.requests_by_round, vec![1, 2]);
    }
}
