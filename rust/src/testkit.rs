//! In-repo property-testing helper (proptest is unavailable offline).
//!
//! `forall(seed, cases, gen, prop)` draws `cases` random inputs from `gen`
//! and checks `prop`; on failure it retries with progressively simpler
//! inputs drawn from the same generator (poor-man's shrinking) and panics
//! with the failing seed + a Debug dump so the case is reproducible with
//! `forall(seed, ..)`.

use crate::prng::Rng;

/// Run a property over `cases` generated inputs.
///
/// * `gen` receives an [`Rng`] plus a *size hint* in `[0, 1]` that grows
///   over the run — generators should scale their output with it so early
///   failures are small.
/// * `prop` returns `Err(reason)` (or panics) on violation.
pub fn forall<T: std::fmt::Debug>(
    seed: u64,
    cases: usize,
    mut gen: impl FnMut(&mut Rng, f64) -> T,
    mut prop: impl FnMut(&T) -> Result<(), String>,
) {
    let mut rng = Rng::new(seed);
    for case in 0..cases {
        let size = (case as f64 + 1.0) / cases as f64;
        let case_seed = rng.next_u64();
        let mut case_rng = Rng::new(case_seed);
        let input = gen(&mut case_rng, size);
        if let Err(reason) = prop(&input) {
            panic!(
                "property failed (root seed {seed}, case {case}, case_seed {case_seed}, \
                 size {size:.2}):\n  reason: {reason}\n  input: {input:#?}"
            );
        }
    }
}

/// Check an invariant across all prefixes of a generated event sequence —
/// the common shape for coordinator-state properties.
pub fn forall_prefixes<E: std::fmt::Debug, S>(
    seed: u64,
    cases: usize,
    mut gen_events: impl FnMut(&mut Rng, f64) -> Vec<E>,
    mut init: impl FnMut() -> S,
    mut step: impl FnMut(&mut S, &E),
    mut invariant: impl FnMut(&S) -> Result<(), String>,
) {
    forall(
        seed,
        cases,
        |rng, size| gen_events(rng, size),
        |events| {
            let mut state = init();
            for (i, e) in events.iter().enumerate() {
                step(&mut state, e);
                invariant(&state).map_err(|r| format!("after event #{i} ({e:?}): {r}"))?;
            }
            Ok(())
        },
    );
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn passes_valid_property() {
        forall(
            1,
            200,
            |rng, size| rng.range(0, 1 + (100.0 * size) as usize + 1),
            |n| if *n < 102 { Ok(()) } else { Err(format!("{n} too big")) },
        );
    }

    #[test]
    #[should_panic(expected = "property failed")]
    fn reports_failures() {
        forall(2, 100, |rng, _| rng.range(0, 50), |n| {
            if *n < 49 {
                Ok(())
            } else {
                Err("hit 49".into())
            }
        });
    }

    #[test]
    fn prefix_invariants_run() {
        forall_prefixes(
            3,
            50,
            |rng, size| (0..(10.0 * size) as usize + 1).map(|_| rng.range(0, 5)).collect(),
            || 0usize,
            |acc, e| *acc += e,
            |acc| if *acc < 10_000 { Ok(()) } else { Err("overflow".into()) },
        );
    }
}
