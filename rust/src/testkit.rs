//! In-repo property-testing helper (proptest is unavailable offline),
//! plus [`FailpointFs`] — the deterministic kill-point crash injector for
//! the durability subsystem.
//!
//! `forall(seed, cases, gen, prop)` draws `cases` random inputs from `gen`
//! and checks `prop`; on failure it retries with progressively simpler
//! inputs drawn from the same generator (poor-man's shrinking) and panics
//! with the failing seed + a Debug dump so the case is reproducible with
//! `forall(seed, ..)`.

use std::collections::BTreeMap;
use std::sync::{Arc, Mutex};

use crate::persist::{MemFs, PersistFs, ShipTransport, Shipment};
use crate::prng::Rng;

/// A [`PersistFs`] that simulates power loss after a byte budget: once the
/// budget is spent, nothing else ever reaches "disk". Appends are
/// truncated at the exact budget boundary (a torn frame), atomic `write`s
/// happen entirely or not at all, and removals stop — precisely the
/// failure model a crash-consistent log must absorb. The kill-point
/// harness in `tests/durability.rs` arms the budget at every byte offset
/// of a recorded run and asserts recovery always lands on a frame
/// boundary's state.
#[derive(Clone)]
pub struct FailpointFs {
    inner: MemFs,
    /// Remaining write bytes before the simulated power loss; `None` = no
    /// failpoint armed (writes unrestricted).
    budget: Arc<Mutex<Option<u64>>>,
    fsync: Arc<Mutex<FsyncState>>,
}

/// Fsync-barrier failure model: a volatile write cache (appends are lost
/// on power failure unless covered by a `sync`) plus injectable sync
/// faults.
#[derive(Default)]
struct FsyncState {
    /// When set, appended bytes sit in a volatile cache until `sync`;
    /// [`FailpointFs::crash_lose_unsynced`] discards everything past the
    /// last synced length. Atomic `write`s (tmp + rename) are modeled as
    /// immediately durable, matching the manifest-commit assumption.
    volatile: bool,
    synced_len: BTreeMap<String, u64>,
    /// This many upcoming `sync` calls fail with an injected I/O error.
    fail_syncs: u32,
}

impl FailpointFs {
    /// Wrap `inner` with no failpoint armed.
    pub fn new(inner: MemFs) -> FailpointFs {
        FailpointFs {
            inner,
            budget: Arc::new(Mutex::new(None)),
            fsync: Arc::new(Mutex::new(FsyncState::default())),
        }
    }

    /// Switch on the volatile write cache. Files existing now are taken
    /// as fully durable; from here on, appended bytes only survive
    /// [`Self::crash_lose_unsynced`] once a `sync` covers them.
    pub fn enable_volatile(&self) {
        let mut st = self.fsync.lock().unwrap();
        st.volatile = true;
        st.synced_len = self.inner.sizes().into_iter().collect();
    }

    /// Inject failures into the next `n` `sync` calls.
    pub fn fail_next_syncs(&self, n: u32) {
        self.fsync.lock().unwrap().fail_syncs = n;
    }

    /// Simulate power loss with the volatile cache unflushed: every file
    /// is truncated to its last synced length; files never synced (and
    /// never atomically written) vanish entirely.
    pub fn crash_lose_unsynced(&self) {
        let st = self.fsync.lock().unwrap();
        let mut disk = self.inner.clone();
        for (name, len) in self.inner.sizes() {
            match st.synced_len.get(&name) {
                Some(&synced) if synced < len => {
                    let mut bytes = self.inner.file(&name).unwrap_or_default();
                    bytes.truncate(synced as usize);
                    self.inner.put(&name, bytes);
                }
                Some(_) => {}
                None => disk.remove(&name),
            }
        }
    }

    /// Arm (or disarm with `None`) the byte budget. Clones share it.
    pub fn set_budget(&self, bytes: Option<u64>) {
        *self.budget.lock().unwrap() = bytes;
    }

    /// Remaining budget, if armed.
    pub fn remaining(&self) -> Option<u64> {
        *self.budget.lock().unwrap()
    }

    /// The backing in-memory filesystem (what "survives the crash").
    pub fn inner(&self) -> &MemFs {
        &self.inner
    }

    /// Consume up to `want` bytes; returns how many may still be written.
    fn consume(&self, want: u64) -> u64 {
        let mut b = self.budget.lock().unwrap();
        match *b {
            None => want,
            Some(left) => {
                let grant = left.min(want);
                *b = Some(left - grant);
                grant
            }
        }
    }
}

impl PersistFs for FailpointFs {
    fn read(&self, name: &str) -> Option<Vec<u8>> {
        self.inner.file(name)
    }

    fn write(&mut self, name: &str, bytes: &[u8]) -> std::io::Result<()> {
        // Atomic replace: all-or-nothing under the budget.
        let granted = self.consume(bytes.len() as u64);
        if granted < bytes.len() as u64 {
            return Ok(()); // power died before the rename committed
        }
        self.inner.write(name, bytes)?;
        let mut st = self.fsync.lock().unwrap();
        if st.volatile {
            // tmp + rename is modeled as durable at commit.
            st.synced_len.insert(name.to_string(), bytes.len() as u64);
        }
        Ok(())
    }

    fn append(&mut self, name: &str, bytes: &[u8]) -> std::io::Result<()> {
        let granted = self.consume(bytes.len() as u64) as usize;
        if granted > 0 {
            self.inner.append(name, &bytes[..granted])?;
        }
        Ok(())
    }

    fn remove(&mut self, name: &str) {
        if self.consume(1) == 1 {
            self.inner.remove(name);
            self.fsync.lock().unwrap().synced_len.remove(name);
        }
    }

    /// Fsync barrier: consumes no byte budget (barriers move no data).
    /// Subject to injected failures; on success, marks the file's current
    /// length as surviving [`FailpointFs::crash_lose_unsynced`].
    fn sync(&mut self, name: &str) -> std::io::Result<()> {
        let mut st = self.fsync.lock().unwrap();
        if st.fail_syncs > 0 {
            st.fail_syncs -= 1;
            return Err(std::io::Error::other("injected fsync failure"));
        }
        if st.volatile {
            let len = self.inner.file(name).map_or(0, |b| b.len() as u64);
            st.synced_len.insert(name.to_string(), len);
        }
        Ok(())
    }
}

/// A shared throttle on [`FailpointTransport`] fault rates: every
/// configured probability is multiplied by the dial's current scale, so a
/// chaos runner can open a transport-fault *burst* (`set(1.0)`) and close
/// it again (`set(0.0)`) mid-run without rebuilding the transports.
/// Clones share the scale. The RNG draw schedule is unchanged by the
/// dial — a probability of `p * 0.0` still consumes the same draws as
/// `p * 1.0` — so runs with identical seeds stay comparable.
#[derive(Clone)]
pub struct FaultDial {
    scale: Arc<Mutex<f64>>,
}

impl FaultDial {
    /// A dial starting at `scale` (1.0 = configured rates, 0.0 = off).
    pub fn new(scale: f64) -> FaultDial {
        FaultDial { scale: Arc::new(Mutex::new(scale)) }
    }

    pub fn set(&self, scale: f64) {
        *self.scale.lock().unwrap() = scale;
    }

    pub fn get(&self) -> f64 {
        *self.scale.lock().unwrap()
    }
}

impl Default for FaultDial {
    fn default() -> FaultDial {
        FaultDial::new(1.0)
    }
}

/// A [`ShipTransport`] that injects the classic network faults — drops,
/// duplicates, and stale (reordered) re-deliveries — deterministically
/// from a seed. Wraps a real transport: `Err` returns mean the shipment
/// never arrived; `Ok` means it arrived at least once, possibly twice,
/// and possibly with an *older* shipment replayed just before it.
pub struct FailpointTransport {
    inner: Box<dyn ShipTransport>,
    rng: Rng,
    drop_p: f64,
    dup_p: f64,
    stale_p: f64,
    dial: Option<FaultDial>,
    held: Option<(usize, Shipment)>,
}

impl FailpointTransport {
    pub fn new(
        inner: Box<dyn ShipTransport>,
        seed: u64,
        drop_p: f64,
        dup_p: f64,
        stale_p: f64,
    ) -> FailpointTransport {
        FailpointTransport {
            inner,
            rng: Rng::new(seed),
            drop_p,
            dup_p,
            stale_p,
            dial: None,
            held: None,
        }
    }

    /// Attach a shared [`FaultDial`] scaling all three fault rates.
    pub fn with_dial(mut self, dial: FaultDial) -> FailpointTransport {
        self.dial = Some(dial);
        self
    }

    fn scaled(&self, p: f64) -> f64 {
        p * self.dial.as_ref().map_or(1.0, FaultDial::get)
    }
}

impl ShipTransport for FailpointTransport {
    fn deliver(&mut self, source: usize, shipment: &Shipment) -> Result<u64, String> {
        if self.rng.chance(self.scaled(self.drop_p)) {
            return Err("injected transport drop".to_string());
        }
        if let Some((src, stale)) = self.held.take() {
            // An old shipment finally arrives, out of order.
            self.inner.deliver(src, &stale)?;
        }
        let watermark = self.inner.deliver(source, shipment)?;
        if self.rng.chance(self.scaled(self.dup_p)) {
            self.inner.deliver(source, shipment)?;
        }
        if self.rng.chance(self.scaled(self.stale_p)) {
            self.held = Some((source, shipment.clone()));
        }
        Ok(watermark)
    }
}

/// Run a property over `cases` generated inputs.
///
/// * `gen` receives an [`Rng`] plus a *size hint* in `[0, 1]` that grows
///   over the run — generators should scale their output with it so early
///   failures are small.
/// * `prop` returns `Err(reason)` (or panics) on violation.
pub fn forall<T: std::fmt::Debug>(
    seed: u64,
    cases: usize,
    mut gen: impl FnMut(&mut Rng, f64) -> T,
    mut prop: impl FnMut(&T) -> Result<(), String>,
) {
    let mut rng = Rng::new(seed);
    for case in 0..cases {
        let size = (case as f64 + 1.0) / cases as f64;
        let case_seed = rng.next_u64();
        let mut case_rng = Rng::new(case_seed);
        let input = gen(&mut case_rng, size);
        if let Err(reason) = prop(&input) {
            panic!(
                "property failed (root seed {seed}, case {case}, case_seed {case_seed}, \
                 size {size:.2}):\n  reason: {reason}\n  input: {input:#?}"
            );
        }
    }
}

/// Check an invariant across all prefixes of a generated event sequence —
/// the common shape for coordinator-state properties.
pub fn forall_prefixes<E: std::fmt::Debug, S>(
    seed: u64,
    cases: usize,
    mut gen_events: impl FnMut(&mut Rng, f64) -> Vec<E>,
    mut init: impl FnMut() -> S,
    mut step: impl FnMut(&mut S, &E),
    mut invariant: impl FnMut(&S) -> Result<(), String>,
) {
    forall(
        seed,
        cases,
        |rng, size| gen_events(rng, size),
        |events| {
            let mut state = init();
            for (i, e) in events.iter().enumerate() {
                step(&mut state, e);
                invariant(&state).map_err(|r| format!("after event #{i} ({e:?}): {r}"))?;
            }
            Ok(())
        },
    );
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn failpoint_truncates_appends_at_the_budget() {
        let mem = MemFs::new();
        let mut fp = FailpointFs::new(mem.clone());
        fp.append("w.log", b"abcdef").unwrap();
        assert_eq!(mem.file("w.log").unwrap(), b"abcdef");

        fp.set_budget(Some(4));
        fp.append("w.log", b"ghijkl").unwrap(); // only 4 bytes land
        assert_eq!(mem.file("w.log").unwrap(), b"abcdefghij");
        assert_eq!(fp.remaining(), Some(0));
        fp.append("w.log", b"mn").unwrap(); // nothing lands
        assert_eq!(mem.file("w.log").unwrap(), b"abcdefghij");

        // Atomic writes are all-or-nothing: with 0 budget the replace
        // never happens; with enough budget it does.
        fp.write("m.json", b"{}").unwrap();
        assert!(mem.file("m.json").is_none());
        fp.set_budget(Some(2));
        fp.write("m.json", b"{}").unwrap();
        assert_eq!(mem.file("m.json").unwrap(), b"{}");
        // Removal after death is impossible.
        fp.remove("m.json");
        assert!(mem.file("m.json").is_some());
        fp.set_budget(None);
        fp.remove("m.json");
        assert!(mem.file("m.json").is_none());
        assert!(fp.read("w.log").is_some());
        assert!(fp.inner().file("w.log").is_some());
    }

    #[test]
    fn volatile_cache_loses_unsynced_appends_and_sync_can_fail() {
        let mem = MemFs::new();
        let mut fp = FailpointFs::new(mem.clone());
        fp.append("pre.log", b"durable").unwrap();
        fp.enable_volatile();

        fp.append("pre.log", b"+cached").unwrap();
        fp.append("new.log", b"never-synced").unwrap();
        fp.write("m.json", b"{}").unwrap(); // atomic replace = durable
        fp.append("synced.log", b"ab").unwrap();
        fp.sync("synced.log").unwrap();
        fp.append("synced.log", b"cd").unwrap();

        // Injected sync failure leaves the cache dirty.
        fp.fail_next_syncs(1);
        assert!(fp.sync("synced.log").is_err());

        fp.crash_lose_unsynced();
        assert_eq!(mem.file("pre.log").unwrap(), b"durable");
        assert!(mem.file("new.log").is_none(), "never synced, never written");
        assert_eq!(mem.file("m.json").unwrap(), b"{}");
        assert_eq!(mem.file("synced.log").unwrap(), b"ab");

        // After the injected failure drains, sync works again.
        fp.append("synced.log", b"ef").unwrap();
        fp.sync("synced.log").unwrap();
        fp.crash_lose_unsynced();
        assert_eq!(mem.file("synced.log").unwrap(), b"abef");
    }

    #[test]
    fn failpoint_transport_faults_never_lose_acked_frames() {
        use crate::persist::{ReplicaStore, Shipper};
        // Heavy fault rates; the shipper's retry + the replica's
        // idempotent apply must still converge to a complete copy.
        let store = ReplicaStore::new();
        let faulty =
            FailpointTransport::new(Box::new(store.clone()), 0xF417, 0.4, 0.3, 0.3);
        let mut sh = Shipper::new(0, Box::new(faulty), 32);
        sh.prime(0, None, vec![]);
        for seq in 0..40u64 {
            sh.stage(seq, format!("event-{seq}").into_bytes());
            sh.flush();
        }
        let mut spins = 0;
        while !sh.is_drained() {
            sh.flush();
            spins += 1;
            assert!(spins < 10_000, "shipping must converge: {:?}", sh.receipt());
        }
        assert!(sh.receipt().failed.is_none());
        assert_eq!(store.watermark(0), 40);
        let replica = store.replica(0).unwrap();
        assert_eq!(replica.frames.len(), 40);
        for (i, f) in replica.frames.iter().enumerate() {
            assert_eq!(f, format!("event-{i}").as_bytes(), "frame {i} intact and in order");
        }
    }

    #[test]
    fn passes_valid_property() {
        forall(
            1,
            200,
            |rng, size| rng.range(0, 1 + (100.0 * size) as usize + 1),
            |n| if *n < 102 { Ok(()) } else { Err(format!("{n} too big")) },
        );
    }

    #[test]
    #[should_panic(expected = "property failed")]
    fn reports_failures() {
        forall(2, 100, |rng, _| rng.range(0, 50), |n| {
            if *n < 49 {
                Ok(())
            } else {
                Err("hit 49".into())
            }
        });
    }

    #[test]
    fn prefix_invariants_run() {
        forall_prefixes(
            3,
            50,
            |rng, size| (0..(10.0 * size) as usize + 1).map(|_| rng.range(0, 5)).collect(),
            || 0usize,
            |acc, e| *acc += e,
            |acc| if *acc < 10_000 { Ok(()) } else { Err("overflow".into()) },
        );
    }
}
