//! In-repo property-testing helper (proptest is unavailable offline),
//! plus [`FailpointFs`] — the deterministic kill-point crash injector for
//! the durability subsystem.
//!
//! `forall(seed, cases, gen, prop)` draws `cases` random inputs from `gen`
//! and checks `prop`; on failure it retries with progressively simpler
//! inputs drawn from the same generator (poor-man's shrinking) and panics
//! with the failing seed + a Debug dump so the case is reproducible with
//! `forall(seed, ..)`.

use std::sync::{Arc, Mutex};

use crate::persist::{MemFs, PersistFs};
use crate::prng::Rng;

/// A [`PersistFs`] that simulates power loss after a byte budget: once the
/// budget is spent, nothing else ever reaches "disk". Appends are
/// truncated at the exact budget boundary (a torn frame), atomic `write`s
/// happen entirely or not at all, and removals stop — precisely the
/// failure model a crash-consistent log must absorb. The kill-point
/// harness in `tests/durability.rs` arms the budget at every byte offset
/// of a recorded run and asserts recovery always lands on a frame
/// boundary's state.
#[derive(Clone)]
pub struct FailpointFs {
    inner: MemFs,
    /// Remaining write bytes before the simulated power loss; `None` = no
    /// failpoint armed (writes unrestricted).
    budget: Arc<Mutex<Option<u64>>>,
}

impl FailpointFs {
    /// Wrap `inner` with no failpoint armed.
    pub fn new(inner: MemFs) -> FailpointFs {
        FailpointFs { inner, budget: Arc::new(Mutex::new(None)) }
    }

    /// Arm (or disarm with `None`) the byte budget. Clones share it.
    pub fn set_budget(&self, bytes: Option<u64>) {
        *self.budget.lock().unwrap() = bytes;
    }

    /// Remaining budget, if armed.
    pub fn remaining(&self) -> Option<u64> {
        *self.budget.lock().unwrap()
    }

    /// The backing in-memory filesystem (what "survives the crash").
    pub fn inner(&self) -> &MemFs {
        &self.inner
    }

    /// Consume up to `want` bytes; returns how many may still be written.
    fn consume(&self, want: u64) -> u64 {
        let mut b = self.budget.lock().unwrap();
        match *b {
            None => want,
            Some(left) => {
                let grant = left.min(want);
                *b = Some(left - grant);
                grant
            }
        }
    }
}

impl PersistFs for FailpointFs {
    fn read(&self, name: &str) -> Option<Vec<u8>> {
        self.inner.file(name)
    }

    fn write(&mut self, name: &str, bytes: &[u8]) -> std::io::Result<()> {
        // Atomic replace: all-or-nothing under the budget.
        let granted = self.consume(bytes.len() as u64);
        if granted < bytes.len() as u64 {
            return Ok(()); // power died before the rename committed
        }
        self.inner.write(name, bytes)
    }

    fn append(&mut self, name: &str, bytes: &[u8]) -> std::io::Result<()> {
        let granted = self.consume(bytes.len() as u64) as usize;
        if granted > 0 {
            self.inner.append(name, &bytes[..granted])?;
        }
        Ok(())
    }

    fn remove(&mut self, name: &str) {
        if self.consume(1) == 1 {
            self.inner.remove(name);
        }
    }
}

/// Run a property over `cases` generated inputs.
///
/// * `gen` receives an [`Rng`] plus a *size hint* in `[0, 1]` that grows
///   over the run — generators should scale their output with it so early
///   failures are small.
/// * `prop` returns `Err(reason)` (or panics) on violation.
pub fn forall<T: std::fmt::Debug>(
    seed: u64,
    cases: usize,
    mut gen: impl FnMut(&mut Rng, f64) -> T,
    mut prop: impl FnMut(&T) -> Result<(), String>,
) {
    let mut rng = Rng::new(seed);
    for case in 0..cases {
        let size = (case as f64 + 1.0) / cases as f64;
        let case_seed = rng.next_u64();
        let mut case_rng = Rng::new(case_seed);
        let input = gen(&mut case_rng, size);
        if let Err(reason) = prop(&input) {
            panic!(
                "property failed (root seed {seed}, case {case}, case_seed {case_seed}, \
                 size {size:.2}):\n  reason: {reason}\n  input: {input:#?}"
            );
        }
    }
}

/// Check an invariant across all prefixes of a generated event sequence —
/// the common shape for coordinator-state properties.
pub fn forall_prefixes<E: std::fmt::Debug, S>(
    seed: u64,
    cases: usize,
    mut gen_events: impl FnMut(&mut Rng, f64) -> Vec<E>,
    mut init: impl FnMut() -> S,
    mut step: impl FnMut(&mut S, &E),
    mut invariant: impl FnMut(&S) -> Result<(), String>,
) {
    forall(
        seed,
        cases,
        |rng, size| gen_events(rng, size),
        |events| {
            let mut state = init();
            for (i, e) in events.iter().enumerate() {
                step(&mut state, e);
                invariant(&state).map_err(|r| format!("after event #{i} ({e:?}): {r}"))?;
            }
            Ok(())
        },
    );
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn failpoint_truncates_appends_at_the_budget() {
        let mem = MemFs::new();
        let mut fp = FailpointFs::new(mem.clone());
        fp.append("w.log", b"abcdef").unwrap();
        assert_eq!(mem.file("w.log").unwrap(), b"abcdef");

        fp.set_budget(Some(4));
        fp.append("w.log", b"ghijkl").unwrap(); // only 4 bytes land
        assert_eq!(mem.file("w.log").unwrap(), b"abcdefghij");
        assert_eq!(fp.remaining(), Some(0));
        fp.append("w.log", b"mn").unwrap(); // nothing lands
        assert_eq!(mem.file("w.log").unwrap(), b"abcdefghij");

        // Atomic writes are all-or-nothing: with 0 budget the replace
        // never happens; with enough budget it does.
        fp.write("m.json", b"{}").unwrap();
        assert!(mem.file("m.json").is_none());
        fp.set_budget(Some(2));
        fp.write("m.json", b"{}").unwrap();
        assert_eq!(mem.file("m.json").unwrap(), b"{}");
        // Removal after death is impossible.
        fp.remove("m.json");
        assert!(mem.file("m.json").is_some());
        fp.set_budget(None);
        fp.remove("m.json");
        assert!(mem.file("m.json").is_none());
        assert!(fp.read("w.log").is_some());
        assert!(fp.inner().file("w.log").is_some());
    }

    #[test]
    fn passes_valid_property() {
        forall(
            1,
            200,
            |rng, size| rng.range(0, 1 + (100.0 * size) as usize + 1),
            |n| if *n < 102 { Ok(()) } else { Err(format!("{n} too big")) },
        );
    }

    #[test]
    #[should_panic(expected = "property failed")]
    fn reports_failures() {
        forall(2, 100, |rng, _| rng.range(0, 50), |n| {
            if *n < 49 {
                Ok(())
            } else {
                Err("hit 49".into())
            }
        });
    }

    #[test]
    fn prefix_invariants_run() {
        forall_prefixes(
            3,
            50,
            |rng, size| (0..(10.0 * size) as usize + 1).map(|_| rng.range(0, 5)).collect(),
            || 0usize,
            |acc, e| *acc += e,
            |acc| if *acc < 10_000 { Ok(()) } else { Err("overflow".into()) },
        );
    }
}
