//! Deterministic, low-overhead observability: a span/event [`Tracer`]
//! with per-shard fixed-capacity ring buffers, and a shard-mergeable
//! metrics [`Registry`] that unifies the counters scattered across
//! [`RunMetrics`](crate::metrics::RunMetrics), journal stats, ship
//! diagnostics, and battery meters into one named namespace.
//!
//! Design constraints, in order:
//!
//! * **Deterministic.** Span IDs are per-shard sequence numbers (no
//!   global state, no wall clock); timestamps are *virtual*: one
//!   simulated tick maps to one millisecond of trace time, and a
//!   per-tick sub-counter orders the (instantaneous) work done inside a
//!   tick. Two runs with the same seed export byte-identical traces.
//! * **Zero allocation on the hot path.** The ring buffer and the open-
//!   span stack are pre-allocated at [`Tracer::new`]; recording a span
//!   writes a [`SpanRec`] (a `Copy` struct) into the ring and never
//!   grows anything. Wrapping silently evicts the oldest records and
//!   counts them in [`Tracer::wrapped`].
//! * **Off by default, free when off.** Every instrumented call site
//!   goes through the free helpers ([`begin`], [`end`], [`marker`]) on
//!   an `&mut Option<Tracer>`; with `None` they are a branch and a
//!   return. The helpers are free functions (not methods) so call
//!   sites that already hold a disjoint field borrow — e.g. the
//!   journal during a seal — still compile.
//!
//! The [`Registry`] is the opposite of the tracer: always available
//! (it is a pure snapshot of state the service already keeps), built on
//! demand, and merged across shards exactly like fleet receipts —
//! counters and gauges sum, labels union under per-shard keys,
//! histograms bucket-merge. A one-worker fleet's registry is
//! byte-identical to the unsharded service's, the same keystone
//! property the receipts uphold.

use std::collections::BTreeMap;

use crate::load::LatencyHistogram;
use crate::util::Json;

pub mod budget;
pub mod export;

/// Ring capacity of one tracer: enough for the span-heaviest bench run
/// (a few spans per request over a few thousand requests) while keeping
/// a 16-shard fleet's trace memory under ~10 MB. Not a knob: a fixed
/// capacity is what makes the hot path allocation-free.
pub const DEFAULT_RING_CAP: usize = 8192;

/// Virtual-time scale: trace timestamps are `tick * TICK_US + sub`,
/// i.e. one simulated tick renders as 1 ms (1000 µs) in a Chrome trace
/// viewer, with up to `TICK_US` intra-tick steps ordered by the
/// sub-counter.
pub const TICK_US: u64 = 1_000;

/// One completed span or instant marker. `Copy` so the ring buffer is
/// a flat pre-allocated array; names are `&'static str` so recording
/// never allocates.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct SpanRec {
    /// Unique within a run: `seq * 1024 + shard_lane`, which stays well
    /// under 2^53 (JSON numbers are f64) for any plausible run length.
    pub id: u64,
    /// Enclosing span's `id`, or 0 for a root. Roots spawned by a
    /// fleet drain carry the front-end span's id across the channel
    /// boundary.
    pub parent: u64,
    pub name: &'static str,
    /// Worker shard index, or `u32::MAX` for the fleet front-end.
    pub shard: u32,
    /// 0 = span, 1 = instant marker.
    pub kind: u8,
    /// Virtual begin/end timestamps (`tick * TICK_US + sub`).
    pub begin_ts: u64,
    pub end_ts: u64,
    /// Simulated ticks the span opened and closed on.
    pub begin_tick: u64,
    pub end_tick: u64,
    /// One span-specific payload (requests served, bytes shipped, ...).
    pub detail: u64,
    /// Per-tracer record sequence; chronological within a shard.
    pub seq: u64,
}

impl SpanRec {
    pub fn is_marker(&self) -> bool {
        self.kind == 1
    }

    /// Virtual duration in trace microseconds.
    pub fn dur(&self) -> u64 {
        self.end_ts.saturating_sub(self.begin_ts)
    }
}

/// A span begun but not yet ended; lives only on the tracer's stack.
#[derive(Clone, Copy, Debug)]
struct OpenSpan {
    id: u64,
    parent: u64,
    name: &'static str,
    begin_ts: u64,
    begin_tick: u64,
}

/// Per-shard span recorder. See the module docs for the design; the
/// important invariants are that [`Tracer::begin`]/[`Tracer::end`]
/// never allocate after construction and that every stamp is strictly
/// monotone within a shard.
#[derive(Clone, Debug)]
pub struct Tracer {
    shard: u32,
    cap: usize,
    /// Ring of completed records; grows (within `cap`) only until the
    /// first wrap, then overwrites in place.
    buf: Vec<SpanRec>,
    /// Next ring slot to overwrite once `buf` is full.
    head: usize,
    /// Records ever recorded (`total - buf.len()` were evicted).
    total: u64,
    next_seq: u64,
    /// Open-span stack, pre-allocated; deeper nests than its capacity
    /// would reallocate, but the instrumented call graph is ~4 deep.
    stack: Vec<OpenSpan>,
    /// Parent id adopted by the next root span (set by the fleet
    /// front-end across the worker channel boundary, 0 = none).
    pending_parent: u64,
    /// Virtual clock: last tick stamped and the intra-tick sub-step.
    last_tick: u64,
    sub: u64,
}

impl Tracer {
    pub fn new(shard: u32) -> Tracer {
        Tracer::with_capacity(shard, DEFAULT_RING_CAP)
    }

    pub fn with_capacity(shard: u32, cap: usize) -> Tracer {
        let cap = cap.max(1);
        Tracer {
            shard,
            cap,
            buf: Vec::with_capacity(cap),
            head: 0,
            total: 0,
            next_seq: 0,
            stack: Vec::with_capacity(64),
            pending_parent: 0,
            // Not a real tick: forces the first stamp to reset `sub`.
            last_tick: u64::MAX,
            sub: 0,
        }
    }

    pub fn shard(&self) -> u32 {
        self.shard
    }

    /// Records ever recorded, including any evicted by ring wrap.
    pub fn total(&self) -> u64 {
        self.total
    }

    /// Records evicted by ring wrap (0 until the ring fills).
    pub fn wrapped(&self) -> u64 {
        self.total.saturating_sub(self.buf.len() as u64)
    }

    /// Virtual timestamp for `tick`, strictly increasing per call.
    fn stamp(&mut self, tick: u64) -> u64 {
        if tick != self.last_tick {
            self.last_tick = tick;
            self.sub = 0;
        } else if self.sub < TICK_US - 1 {
            // Saturate rather than spill into the next tick's window;
            // ~1000 events inside one tick is far past the ring anyway.
            self.sub += 1;
        }
        tick * TICK_US + self.sub
    }

    fn make_id(&mut self) -> u64 {
        self.next_seq += 1;
        self.next_seq * 1024 + (u64::from(self.shard) + 1).min(1023)
    }

    fn push(&mut self, rec: SpanRec) {
        if self.buf.len() < self.cap {
            self.buf.push(rec);
        } else {
            self.buf[self.head] = rec;
            self.head = (self.head + 1) % self.cap;
        }
        self.total += 1;
    }

    /// Open a span nested under the current stack top (or rootless if
    /// the stack is empty). Returns the span id to pass to [`end`].
    pub fn begin(&mut self, name: &'static str, tick: u64) -> u64 {
        let parent = self.stack.last().map_or(0, |s| s.id);
        self.begin_with_parent(name, tick, parent)
    }

    /// Open a new *root* span: any span still open (an error path that
    /// unwound past its `end`) is force-closed first, and the pending
    /// cross-boundary parent, if one was adopted, links this root to
    /// the fleet front-end span that dispatched it.
    pub fn begin_root(&mut self, name: &'static str, tick: u64) -> u64 {
        while !self.stack.is_empty() {
            let straggler = self.stack.last().map_or(0, |s| s.id);
            self.end(straggler, tick, 0);
        }
        let parent = std::mem::take(&mut self.pending_parent);
        self.begin_with_parent(name, tick, parent)
    }

    fn begin_with_parent(&mut self, name: &'static str, tick: u64, parent: u64) -> u64 {
        let id = self.make_id();
        let begin_ts = self.stamp(tick);
        self.stack.push(OpenSpan { id, parent, name, begin_ts, begin_tick: tick });
        id
    }

    /// Close span `id`, auto-closing any children still open above it
    /// (pop-through). Unknown ids are a no-op, so error paths that
    /// already unwound are safe to `end` again.
    pub fn end(&mut self, id: u64, tick: u64, detail: u64) {
        if !self.stack.iter().any(|s| s.id == id) {
            return;
        }
        while let Some(open) = self.stack.pop() {
            let end_ts = self.stamp(tick);
            self.push(SpanRec {
                id: open.id,
                parent: open.parent,
                name: open.name,
                shard: self.shard,
                kind: 0,
                begin_ts: open.begin_ts,
                end_ts,
                begin_tick: open.begin_tick,
                end_tick: tick,
                detail: if open.id == id { detail } else { 0 },
                seq: self.total,
            });
            if open.id == id {
                break;
            }
        }
    }

    /// Record an instant marker (scenario phase, injected fault) under
    /// the current stack top.
    pub fn marker(&mut self, name: &'static str, tick: u64, detail: u64) {
        let id = self.make_id();
        let parent = self.stack.last().map_or(0, |s| s.id);
        let ts = self.stamp(tick);
        self.push(SpanRec {
            id,
            parent,
            name,
            shard: self.shard,
            kind: 1,
            begin_ts: ts,
            end_ts: ts,
            begin_tick: tick,
            end_tick: tick,
            detail,
            seq: self.total,
        });
    }

    /// Adopt `parent` as the next root span's parent (the fleet
    /// front-end threads its drain span id to workers through this).
    pub fn adopt_parent(&mut self, parent: u64) {
        self.pending_parent = parent;
    }

    /// Completed records in chronological (record) order. Open spans
    /// are not included — they have no end yet.
    pub fn records(&self) -> Vec<SpanRec> {
        let mut out = Vec::with_capacity(self.buf.len());
        if self.buf.len() < self.cap {
            out.extend_from_slice(&self.buf);
        } else {
            out.extend_from_slice(&self.buf[self.head..]);
            out.extend_from_slice(&self.buf[..self.head]);
        }
        out
    }
}

// ---------------------------------------------------------------------
// Free helpers over `Option<Tracer>`
// ---------------------------------------------------------------------
//
// Call sites hold the tracer as an `Option` field and pass `&mut` to
// these; when tracing is off the cost is one branch. They are free
// functions so a method body that has already borrowed a *different*
// field of the same struct (e.g. `self.journal.as_mut()`) can still
// trace — `&mut self.tracer` is a disjoint borrow, `self.method()`
// would not be.

/// [`Tracer::begin`] through an `Option`; returns 0 when disabled.
pub fn begin(t: &mut Option<Tracer>, name: &'static str, tick: u64) -> u64 {
    match t {
        Some(t) => t.begin(name, tick),
        None => 0,
    }
}

/// [`Tracer::begin_root`] through an `Option`; returns 0 when disabled.
pub fn begin_root(t: &mut Option<Tracer>, name: &'static str, tick: u64) -> u64 {
    match t {
        Some(t) => t.begin_root(name, tick),
        None => 0,
    }
}

/// [`Tracer::end`] through an `Option`; no-op when disabled.
pub fn end(t: &mut Option<Tracer>, id: u64, tick: u64, detail: u64) {
    if let Some(t) = t {
        t.end(id, tick, detail);
    }
}

/// [`Tracer::marker`] through an `Option`; no-op when disabled.
pub fn marker(t: &mut Option<Tracer>, name: &'static str, tick: u64, detail: u64) {
    if let Some(t) = t {
        t.marker(name, tick, detail);
    }
}

/// [`Tracer::adopt_parent`] through an `Option`; no-op when disabled.
pub fn adopt_parent(t: &mut Option<Tracer>, parent: u64) {
    if let Some(t) = t {
        t.adopt_parent(parent);
    }
}

// ---------------------------------------------------------------------
// Metrics registry
// ---------------------------------------------------------------------

/// A named snapshot of everything the system counts: monotone counters,
/// point-in-time gauges, free-form labels (error strings, keyed per
/// shard so merges never collide), and latency histograms. Built on
/// demand — it holds no live state — and mergeable across shards with
/// the same semantics as fleet receipts: counters and gauges sum,
/// labels union, histograms bucket-merge.
#[derive(Clone, Debug, Default, PartialEq)]
pub struct Registry {
    counters: BTreeMap<String, u64>,
    gauges: BTreeMap<String, f64>,
    labels: BTreeMap<String, String>,
    hists: BTreeMap<String, LatencyHistogram>,
}

impl Registry {
    pub fn new() -> Registry {
        Registry::default()
    }

    pub fn set_counter(&mut self, name: impl Into<String>, v: u64) {
        self.counters.insert(name.into(), v);
    }

    pub fn set_gauge(&mut self, name: impl Into<String>, v: f64) {
        self.gauges.insert(name.into(), v);
    }

    pub fn set_label(&mut self, name: impl Into<String>, v: impl Into<String>) {
        self.labels.insert(name.into(), v.into());
    }

    pub fn set_hist(&mut self, name: impl Into<String>, h: LatencyHistogram) {
        self.hists.insert(name.into(), h);
    }

    /// Counter value, 0 if absent — missing and zero are the same
    /// question to a consumer ("did anything fail?").
    pub fn counter(&self, name: &str) -> u64 {
        self.counters.get(name).copied().unwrap_or(0)
    }

    pub fn gauge(&self, name: &str) -> f64 {
        self.gauges.get(name).copied().unwrap_or(0.0)
    }

    pub fn label(&self, name: &str) -> Option<&str> {
        self.labels.get(name).map(String::as_str)
    }

    pub fn hist(&self, name: &str) -> Option<&LatencyHistogram> {
        self.hists.get(name)
    }

    /// Fold another shard's registry into this one: counters and gauges
    /// sum, labels union (per-shard key suffixes keep them disjoint),
    /// histograms bucket-merge.
    pub fn merge(&mut self, other: &Registry) {
        for (k, v) in &other.counters {
            *self.counters.entry(k.clone()).or_insert(0) += v;
        }
        for (k, v) in &other.gauges {
            *self.gauges.entry(k.clone()).or_insert(0.0) += v;
        }
        for (k, v) in &other.labels {
            self.labels.insert(k.clone(), v.clone());
        }
        for (k, h) in &other.hists {
            self.hists.entry(k.clone()).or_default().merge(h);
        }
    }

    /// Deterministic JSON (sorted keys throughout): `{counters, gauges,
    /// labels, hists}`.
    pub fn to_json(&self) -> Json {
        let mut counters = Json::obj();
        for (k, v) in &self.counters {
            counters = counters.set(k, *v);
        }
        let mut gauges = Json::obj();
        for (k, v) in &self.gauges {
            gauges = gauges.set(k, *v);
        }
        let mut labels = Json::obj();
        for (k, v) in &self.labels {
            labels = labels.set(k, v.clone());
        }
        let mut hists = Json::obj();
        for (k, h) in &self.hists {
            hists = hists.set(k, h.to_json());
        }
        Json::obj()
            .set("counters", counters)
            .set("gauges", gauges)
            .set("labels", labels)
            .set("hists", hists)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn span_ids_are_deterministic_and_distinct_per_shard() {
        let mut a = Tracer::new(0);
        let mut b = Tracer::new(0);
        let mut c = Tracer::new(3);
        for tick in 0..5 {
            let (x, y, z) = (a.begin("s", tick), b.begin("s", tick), c.begin("s", tick));
            assert_eq!(x, y, "same shard, same schedule => same ids");
            assert_ne!(x, z, "different shard lane => different ids");
            a.end(x, tick, 0);
            b.end(y, tick, 0);
            c.end(z, tick, 0);
        }
        assert_eq!(a.records(), b.records());
    }

    #[test]
    fn virtual_time_is_strictly_monotone_within_a_tick() {
        let mut t = Tracer::new(0);
        let s1 = t.begin("outer", 7);
        let s2 = t.begin("inner", 7);
        t.end(s2, 7, 0);
        t.end(s1, 7, 0);
        let recs = t.records();
        assert_eq!(recs.len(), 2);
        let inner = recs.iter().find(|r| r.name == "inner").unwrap();
        let outer = recs.iter().find(|r| r.name == "outer").unwrap();
        assert!(outer.begin_ts < inner.begin_ts);
        assert!(inner.end_ts < outer.end_ts);
        assert_eq!(inner.parent, outer.id);
        assert_eq!(outer.begin_ts, 7 * TICK_US);
    }

    #[test]
    fn ring_wraps_in_place_without_growing() {
        let mut t = Tracer::with_capacity(0, 8);
        for tick in 0..100u64 {
            let id = t.begin("s", tick);
            t.end(id, tick, tick);
        }
        assert_eq!(t.buf.len(), 8, "ring never outgrows its capacity");
        assert_eq!(t.buf.capacity(), 8);
        assert_eq!(t.total(), 100);
        assert_eq!(t.wrapped(), 92);
        let recs = t.records();
        assert_eq!(recs.len(), 8);
        // Chronological: the eight newest spans, oldest first.
        for (i, r) in recs.iter().enumerate() {
            assert_eq!(r.detail, 92 + i as u64);
        }
    }

    #[test]
    fn end_pops_through_unclosed_children_and_ignores_unknown_ids() {
        let mut t = Tracer::new(0);
        let root = t.begin("root", 1);
        let _child = t.begin("child", 1);
        t.end(0xdead_beef, 1, 0); // unknown id: no-op
        assert_eq!(t.total(), 0);
        t.end(root, 2, 9);
        let recs = t.records();
        assert_eq!(recs.len(), 2, "child auto-closed by popping through");
        assert_eq!(recs[0].name, "child");
        assert_eq!(recs[1].name, "root");
        assert_eq!(recs[1].detail, 9);
    }

    #[test]
    fn begin_root_force_closes_stragglers_and_adopts_parent() {
        let mut t = Tracer::new(0);
        let orphan = t.begin("orphan", 1);
        t.adopt_parent(777);
        let root = t.begin_root("root", 2);
        t.end(root, 2, 0);
        let recs = t.records();
        assert_eq!(recs[0].id, orphan);
        let root_rec = recs.iter().find(|r| r.id == root).unwrap();
        assert_eq!(root_rec.parent, 777, "pending parent consumed by the root");
        let again = t.begin_root("root", 3);
        t.end(again, 3, 0);
        let last = *t.records().last().unwrap();
        assert_eq!(last.parent, 0, "parent adoption is one-shot");
    }

    #[test]
    fn markers_are_instant_and_parented() {
        let mut t = Tracer::new(2);
        let root = t.begin("root", 4);
        t.marker("fault", 4, 3);
        t.end(root, 4, 0);
        let recs = t.records();
        let m = recs.iter().find(|r| r.is_marker()).unwrap();
        assert_eq!(m.begin_ts, m.end_ts);
        assert_eq!(m.parent, root);
        assert_eq!(m.detail, 3);
        assert_eq!(m.dur(), 0);
    }

    #[test]
    fn option_helpers_are_noops_when_disabled() {
        let mut none: Option<Tracer> = None;
        assert_eq!(begin(&mut none, "s", 1), 0);
        assert_eq!(begin_root(&mut none, "s", 1), 0);
        end(&mut none, 0, 1, 0);
        marker(&mut none, "m", 1, 0);
        adopt_parent(&mut none, 5);
        assert!(none.is_none());
    }

    #[test]
    fn registry_merge_sums_counters_unions_labels_merges_hists() {
        let mut a = Registry::new();
        a.set_counter("req.requests", 3);
        a.set_gauge("energy.joules", 1.5);
        a.set_label("ship.last_error.shard0", "timeout");
        let mut ha = LatencyHistogram::new();
        ha.record(1);
        ha.record(4);
        a.set_hist("latency.queue_delay", ha.clone());

        let mut b = Registry::new();
        b.set_counter("req.requests", 2);
        b.set_counter("prunes", 7);
        b.set_gauge("energy.joules", 0.5);
        b.set_label("ship.last_error.shard1", "refused");
        let mut hb = LatencyHistogram::new();
        hb.record(9);
        b.set_hist("latency.queue_delay", hb.clone());

        let mut merged = a.clone();
        merged.merge(&b);
        assert_eq!(merged.counter("req.requests"), 5);
        assert_eq!(merged.counter("prunes"), 7);
        assert!((merged.gauge("energy.joules") - 2.0).abs() < 1e-12);
        assert_eq!(merged.label("ship.last_error.shard0"), Some("timeout"));
        assert_eq!(merged.label("ship.last_error.shard1"), Some("refused"));
        let mut want = ha;
        want.merge(&hb);
        assert_eq!(merged.hist("latency.queue_delay"), Some(&want));
    }

    #[test]
    fn registry_json_is_deterministic() {
        let mut r = Registry::new();
        r.set_counter("b", 2);
        r.set_counter("a", 1);
        r.set_gauge("g", 0.25);
        let one = r.to_json().to_string();
        let two = r.clone().to_json().to_string();
        assert_eq!(one, two);
        assert!(one.find("\"a\"").unwrap() < one.find("\"b\"").unwrap());
    }
}
