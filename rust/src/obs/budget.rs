//! Tick-budget attribution: fold a span trace into a per-phase table
//! answering "where did the run's traced time go?". Every microsecond
//! inside a root span is attributed to exactly one named span as *self*
//! time (its duration minus its same-lane children), so the table's
//! share column sums to 100% of in-span time by construction. The same
//! computation runs over live [`SpanRec`]s (bench/load harness) and
//! over a re-parsed Chrome trace export (the `obs` binary), so the
//! table printed at run time and the one recovered from the artifact
//! agree byte for byte.

use std::collections::BTreeMap;

use crate::util::Json;

use super::export::lane;
use super::SpanRec;

/// One span, decoupled from the in-process record so traces can be
/// re-loaded from their Chrome export.
#[derive(Clone, Debug)]
pub struct BudgetSpan {
    pub name: String,
    /// Export lane (front-end = 0, shard `k` = `k + 1`).
    pub lane: u64,
    pub id: u64,
    pub parent: u64,
    pub ts: u64,
    pub dur: u64,
}

/// Aggregated row for one span name.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct BudgetRow {
    pub name: String,
    pub count: u64,
    pub total_us: u64,
    pub self_us: u64,
}

/// The folded budget: rows sorted by self time (descending, name as the
/// tie-break), plus the totals the share column is computed against.
#[derive(Clone, Debug)]
pub struct Budget {
    pub rows: Vec<BudgetRow>,
    /// Σ durations of root spans (parent absent on the span's lane).
    pub root_us: u64,
    /// Σ self times over every row; equals `root_us` when every span
    /// nests inside a root on its own lane.
    pub attributed_us: u64,
}

/// Lossless conversion from live tracer records (markers drop out —
/// they carry no duration).
pub fn spans_from_records(records: &[SpanRec]) -> Vec<BudgetSpan> {
    records
        .iter()
        .filter(|r| !r.is_marker())
        .map(|r| BudgetSpan {
            name: r.name.to_string(),
            lane: lane(r.shard),
            id: r.id,
            parent: r.parent,
            ts: r.begin_ts,
            dur: r.dur(),
        })
        .collect()
}

/// Recover spans and marker counts from a Chrome trace document (the
/// inverse of [`export::chrome_trace`](super::export::chrome_trace)).
pub fn spans_from_chrome(doc: &Json) -> Result<(Vec<BudgetSpan>, Vec<(String, u64)>), String> {
    let events = doc
        .at(&["traceEvents"])
        .and_then(Json::as_arr)
        .ok_or("not a Chrome trace: no traceEvents array")?;
    let mut spans = Vec::new();
    let mut markers: BTreeMap<String, u64> = BTreeMap::new();
    for e in events {
        let ph = e.at(&["ph"]).and_then(Json::as_str).unwrap_or("");
        let name = e.at(&["name"]).and_then(Json::as_str).unwrap_or("?").to_string();
        let field = |keys: &[&str]| e.at(keys).and_then(Json::as_u64);
        match ph {
            "X" => spans.push(BudgetSpan {
                name,
                lane: field(&["tid"]).ok_or("span event without tid")?,
                id: field(&["args", "id"]).ok_or("span event without args.id")?,
                parent: field(&["args", "parent"]).unwrap_or(0),
                ts: field(&["ts"]).ok_or("span event without ts")?,
                dur: field(&["dur"]).unwrap_or(0),
            }),
            "i" => *markers.entry(name).or_insert(0) += 1,
            _ => {} // metadata ("M") and anything foreign
        }
    }
    Ok((spans, markers.into_iter().collect()))
}

/// Fold spans into the per-name budget. A span is a *root* when its
/// parent id does not resolve on its own lane (parent 0, or a
/// cross-lane parent such as a worker `drain` adopted by the fleet
/// front-end — each lane budgets its own time).
pub fn compute(spans: &[BudgetSpan]) -> Budget {
    let mut by_id: BTreeMap<(u64, u64), usize> = BTreeMap::new();
    for (i, s) in spans.iter().enumerate() {
        by_id.insert((s.lane, s.id), i);
    }
    let mut child_us = vec![0u64; spans.len()];
    let mut root_us = 0u64;
    for s in spans {
        match by_id.get(&(s.lane, s.parent)) {
            Some(&p) if s.parent != 0 => child_us[p] += s.dur,
            _ => root_us += s.dur,
        }
    }
    let mut rows: BTreeMap<&str, BudgetRow> = BTreeMap::new();
    for (i, s) in spans.iter().enumerate() {
        let row = rows.entry(s.name.as_str()).or_insert_with(|| BudgetRow {
            name: s.name.clone(),
            count: 0,
            total_us: 0,
            self_us: 0,
        });
        row.count += 1;
        row.total_us += s.dur;
        row.self_us += s.dur.saturating_sub(child_us[i]);
    }
    let mut rows: Vec<BudgetRow> = rows.into_values().collect();
    rows.sort_by(|a, b| b.self_us.cmp(&a.self_us).then_with(|| a.name.cmp(&b.name)));
    let attributed_us = rows.iter().map(|r| r.self_us).sum();
    Budget { rows, root_us, attributed_us }
}

/// Render the budget (and marker counts, when any) as the fixed-width
/// table the `obs` binary and the load harness both print.
pub fn render(b: &Budget, markers: &[(String, u64)]) -> String {
    let mut out = String::new();
    out.push_str(&format!(
        "{:<16} {:>7} {:>10} {:>10} {:>8}\n",
        "phase", "count", "total_us", "self_us", "share%"
    ));
    for r in &b.rows {
        let share = if b.root_us == 0 {
            0.0
        } else {
            100.0 * r.self_us as f64 / b.root_us as f64
        };
        out.push_str(&format!(
            "{:<16} {:>7} {:>10} {:>10} {:>8.1}\n",
            r.name, r.count, r.total_us, r.self_us, share
        ));
    }
    let pct = if b.root_us == 0 {
        100.0
    } else {
        100.0 * b.attributed_us as f64 / b.root_us as f64
    };
    out.push_str(&format!(
        "in-span time {} us across {} phases; {:.1}% attributed to named spans\n",
        b.root_us,
        b.rows.len(),
        pct
    ));
    if !markers.is_empty() {
        out.push_str("markers:");
        for (name, n) in markers {
            out.push_str(&format!(" {name}={n}"));
        }
        out.push('\n');
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::obs::export::chrome_trace;
    use crate::obs::Tracer;

    fn sample() -> Vec<SpanRec> {
        let mut front = Tracer::new(u32::MAX);
        let root = front.begin_root("fleet_drain", 1);
        front.end(root, 1, 0);
        let mut shard = Tracer::new(0);
        shard.adopt_parent(root);
        let d = shard.begin_root("drain", 1);
        let s = shard.begin("serve", 1);
        shard.marker("fault", 1, 0);
        shard.end(s, 1, 0);
        shard.end(d, 1, 0);
        let mut recs = front.records();
        recs.extend(shard.records());
        recs
    }

    #[test]
    fn attribution_partitions_root_time() {
        let b = compute(&spans_from_records(&sample()));
        // `drain` has a cross-lane parent: it must count as a root of
        // its own lane, and self times must sum to exactly the roots.
        assert_eq!(b.attributed_us, b.root_us);
        assert!(b.rows.iter().any(|r| r.name == "serve"));
        let total: u64 = b
            .rows
            .iter()
            .filter(|r| ["fleet_drain", "drain"].contains(&r.name.as_str()))
            .map(|r| r.total_us)
            .sum();
        assert_eq!(total, b.root_us);
    }

    #[test]
    fn chrome_roundtrip_matches_live_records() {
        let recs = sample();
        let live = compute(&spans_from_records(&recs));
        let doc = Json::parse(&chrome_trace(&recs).to_pretty()).unwrap();
        let (spans, markers) = spans_from_chrome(&doc).unwrap();
        let back = compute(&spans);
        assert_eq!(back.rows, live.rows);
        assert_eq!(back.root_us, live.root_us);
        assert_eq!(markers, vec![("fault".to_string(), 1)]);
        assert_eq!(render(&back, &markers), render(&live, &markers));
    }
}
