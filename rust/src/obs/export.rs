//! Trace exporters: Chrome `trace_event` JSON (load `chrome://tracing`
//! or <https://ui.perfetto.dev> and drop the file in) and a flat JSONL
//! event dump for ad-hoc grepping. Both are deterministic: records are
//! re-sorted by `(begin_ts, lane, seq)` so the byte output depends only
//! on the recorded spans, never on collection order.

use std::path::{Path, PathBuf};

use crate::util::Json;

use super::SpanRec;

/// Chrome trace `tid` lane for a shard: the fleet front-end
/// (`u32::MAX`) renders as lane 0, worker shard `k` as lane `k + 1`.
pub fn lane(shard: u32) -> u64 {
    if shard == u32::MAX {
        0
    } else {
        u64::from(shard) + 1
    }
}

/// Sort records into the canonical export order.
pub fn sort_records(records: &mut [SpanRec]) {
    records.sort_by_key(|r| (r.begin_ts, lane(r.shard), r.seq));
}

fn args(r: &SpanRec) -> Json {
    Json::obj()
        .set("id", r.id)
        .set("parent", r.parent)
        .set("tick", r.begin_tick)
        .set("detail", r.detail)
        .set("seq", r.seq)
}

/// Render records as a Chrome `trace_event` document: one complete
/// (`"X"`) event per span, one instant (`"i"`) event per marker, plus
/// `thread_name` metadata naming each lane. Timestamps are the
/// tracer's virtual microseconds (1 simulated tick = 1 ms on screen).
pub fn chrome_trace(records: &[SpanRec]) -> Json {
    let mut sorted = records.to_vec();
    sort_records(&mut sorted);
    let mut events = Vec::with_capacity(sorted.len() + 8);
    let mut lanes: Vec<u32> = sorted.iter().map(|r| r.shard).collect();
    lanes.sort_by_key(|&s| lane(s));
    lanes.dedup();
    for shard in lanes {
        let name = if shard == u32::MAX {
            "fleet front-end".to_string()
        } else {
            format!("shard {shard}")
        };
        events.push(
            Json::obj()
                .set("ph", "M")
                .set("name", "thread_name")
                .set("pid", 0u64)
                .set("tid", lane(shard))
                .set("args", Json::obj().set("name", name)),
        );
    }
    for r in &sorted {
        let base = Json::obj()
            .set("name", r.name)
            .set("cat", "cause")
            .set("pid", 0u64)
            .set("tid", lane(r.shard))
            .set("ts", r.begin_ts)
            .set("args", args(r));
        events.push(if r.is_marker() {
            base.set("ph", "i").set("s", "t")
        } else {
            base.set("ph", "X").set("dur", r.dur())
        });
    }
    Json::obj()
        .set("displayTimeUnit", "ms")
        .set("traceEvents", Json::Arr(events))
}

/// One compact JSON object per record, one record per line.
pub fn jsonl(records: &[SpanRec]) -> String {
    let mut sorted = records.to_vec();
    sort_records(&mut sorted);
    let mut out = String::new();
    for r in &sorted {
        let line = Json::obj()
            .set("kind", if r.is_marker() { "marker" } else { "span" })
            .set("name", r.name)
            .set("shard", u64::from(lane(r.shard)))
            .set("id", r.id)
            .set("parent", r.parent)
            .set("begin_ts", r.begin_ts)
            .set("end_ts", r.end_ts)
            .set("begin_tick", r.begin_tick)
            .set("end_tick", r.end_tick)
            .set("detail", r.detail)
            .set("seq", r.seq);
        out.push_str(&line.to_string());
        out.push('\n');
    }
    out
}

/// Write both exports under `dir`: `{prefix}_trace.json` (Chrome trace)
/// and `{prefix}_events.jsonl`. Creates `dir` if needed; returns the
/// two paths written.
pub fn write_dir(
    dir: &Path,
    prefix: &str,
    records: &[SpanRec],
) -> std::io::Result<(PathBuf, PathBuf)> {
    std::fs::create_dir_all(dir)?;
    let trace_path = dir.join(format!("{prefix}_trace.json"));
    let jsonl_path = dir.join(format!("{prefix}_events.jsonl"));
    std::fs::write(&trace_path, chrome_trace(records).to_pretty())?;
    std::fs::write(&jsonl_path, jsonl(records))?;
    Ok((trace_path, jsonl_path))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::obs::Tracer;

    fn sample() -> Vec<SpanRec> {
        let mut front = Tracer::new(u32::MAX);
        let root = front.begin_root("fleet_drain", 1);
        front.end(root, 1, 2);
        let mut shard = Tracer::new(0);
        shard.adopt_parent(root);
        let d = shard.begin_root("drain", 1);
        shard.marker("fault", 1, 0);
        shard.end(d, 1, 1);
        let mut recs = front.records();
        recs.extend(shard.records());
        recs
    }

    #[test]
    fn chrome_trace_is_loadable_and_sorted() {
        let recs = sample();
        let doc = chrome_trace(&recs);
        let text = doc.to_pretty();
        let back = Json::parse(&text).expect("round-trips through the parser");
        let events = back.at(&["traceEvents"]).and_then(Json::as_arr).unwrap();
        // 2 lane-name metadata events + 3 records.
        assert_eq!(events.len(), 5);
        let phases: Vec<_> = events
            .iter()
            .map(|e| e.at(&["ph"]).and_then(Json::as_str).unwrap().to_string())
            .collect();
        assert_eq!(phases.iter().filter(|p| *p == "M").count(), 2);
        assert_eq!(phases.iter().filter(|p| *p == "X").count(), 2);
        assert_eq!(phases.iter().filter(|p| *p == "i").count(), 1);
    }

    #[test]
    fn exports_are_order_insensitive_and_deterministic() {
        let recs = sample();
        let mut reversed = recs.clone();
        reversed.reverse();
        assert_eq!(
            chrome_trace(&recs).to_string(),
            chrome_trace(&reversed).to_string()
        );
        assert_eq!(jsonl(&recs), jsonl(&reversed));
        assert_eq!(jsonl(&recs).lines().count(), 3);
    }
}
