//! The append-only event log plus the manifest that names the current
//! snapshot/log generation.
//!
//! Directory layout (inside one [`PersistFs`]):
//!
//! ```text
//! MANIFEST.json        — {version, next_seq, snapshot, log}; atomic replace
//! wal-<seq>.log        — header ‖ frames (one event per frame)
//! snapshot-<seq>.bin   — header ‖ one frame holding the StateImage
//! ```
//!
//! Compaction writes the new snapshot and a fresh empty log *first*, then
//! commits by atomically replacing the manifest, then deletes the old
//! generation. A crash anywhere in that sequence leaves a readable state:
//! before the manifest commit the old generation is intact; after it the
//! new one is; stale files are garbage, not corruption.

use std::io;

use crate::persist::frame::{
    self, encode_frame, header, scan_frames, LOG_MAGIC, SNAP_MAGIC,
};
use crate::persist::PersistFs;
use crate::util::Json;

/// Manifest file name.
pub const MANIFEST: &str = "MANIFEST.json";

/// The committed generation pointer.
#[derive(Clone, Debug, PartialEq)]
pub struct Manifest {
    pub version: u64,
    /// Sequence number of the first event the current log may hold (=
    /// events materialized into the snapshot).
    pub next_seq: u64,
    /// Snapshot file of this generation; `None` before the first
    /// compaction.
    pub snapshot: Option<String>,
    /// Current write-ahead log file.
    pub log: String,
}

impl Manifest {
    fn fresh() -> Manifest {
        Manifest { version: 1, next_seq: 0, snapshot: None, log: "wal-0.log".to_string() }
    }

    fn to_json(&self) -> Json {
        let snap = match &self.snapshot {
            Some(s) => Json::Str(s.clone()),
            None => Json::Null,
        };
        Json::obj()
            .set("version", self.version)
            .set("next_seq", self.next_seq)
            .set("snapshot", snap)
            .set("log", self.log.as_str())
    }

    fn from_json(j: &Json) -> Result<Manifest, String> {
        let num = |k: &str| {
            j.get(k)
                .and_then(Json::as_f64)
                .ok_or_else(|| format!("manifest missing numeric '{k}'"))
        };
        let log = j
            .get("log")
            .and_then(Json::as_str)
            .ok_or("manifest missing 'log'")?
            .to_string();
        let snapshot = match j.get("snapshot") {
            Some(Json::Str(s)) => Some(s.clone()),
            Some(Json::Null) | None => None,
            Some(other) => return Err(format!("manifest 'snapshot' malformed: {other}")),
        };
        Ok(Manifest { version: num("version")? as u64, next_seq: num("next_seq")? as u64, snapshot, log })
    }
}

/// What [`EventLog::open`] found on the filesystem.
pub struct Opened {
    pub log: EventLog,
    /// The committed snapshot payload, if a compaction ever ran.
    pub snapshot: Option<Vec<u8>>,
    /// Complete event frames of the log tail, in order.
    pub frames: Vec<Vec<u8>>,
    /// Torn/corrupt bytes dropped (and repaired away) from the log tail.
    pub torn_bytes: u64,
}

/// The append-only write-ahead log over a [`PersistFs`].
pub struct EventLog {
    fs: Box<dyn PersistFs>,
    manifest: Manifest,
    /// Current log file length in bytes (header included).
    log_len: u64,
    /// Sequence number of the next event to append.
    next_seq: u64,
    /// Events appended to the current log tail (resets on compaction).
    events_in_log: u64,
}

impl EventLog {
    /// Open (or initialize) the log inside `fs`, repairing any torn tail.
    /// The caller replays `snapshot` + `frames`, then continues appending.
    pub fn open(mut fs: Box<dyn PersistFs>) -> io::Result<Opened> {
        let manifest = match fs.read(MANIFEST) {
            Some(bytes) => {
                let text = String::from_utf8(bytes)
                    .map_err(|_| corrupt("manifest is not UTF-8"))?;
                let json = Json::parse(&text)
                    .map_err(|e| corrupt(&format!("manifest parse: {e}")))?;
                Manifest::from_json(&json).map_err(|e| corrupt(&e))?
            }
            None => {
                // Log file first, manifest second: a committed manifest
                // must never name a file that does not exist (a crash
                // between the two writes then simply re-initializes).
                let m = Manifest::fresh();
                fs.write(&m.log, &header(LOG_MAGIC))?;
                fs.write(MANIFEST, (m.to_json().to_pretty() + "\n").as_bytes())?;
                m
            }
        };

        // Snapshot: one frame behind a snapshot header. A manifest that
        // names a snapshot the filesystem lost (or that fails its CRC) is
        // unrecoverable corruption — fail loudly rather than silently
        // dropping materialized history.
        let snapshot = match &manifest.snapshot {
            None => None,
            Some(name) => {
                let bytes = fs
                    .read(name)
                    .ok_or_else(|| corrupt(&format!("snapshot '{name}' missing")))?;
                let (mut frames, _) = scan_frames(&bytes, SNAP_MAGIC);
                if frames.len() != 1 {
                    return Err(corrupt(&format!(
                        "snapshot '{name}' malformed ({} frames)",
                        frames.len()
                    )));
                }
                Some(frames.remove(0))
            }
        };

        // Log tail: keep the valid frame prefix, repair the file if a torn
        // tail (or a short/garbled header) is found. A manifest-named log
        // that is *entirely missing* is loud corruption, like a missing
        // snapshot: both init and compaction write the log file before
        // committing the manifest that names it, so no crash can legally
        // produce this state — silently starting empty would drop the
        // whole acked event tail.
        let raw = fs
            .read(&manifest.log)
            .ok_or_else(|| corrupt(&format!("log '{}' missing", manifest.log)))?;
        let (frames, valid) = scan_frames(&raw, LOG_MAGIC);
        let torn = raw.len() as u64 - valid as u64;
        if torn > 0 || raw.is_empty() {
            // Rewrite to the valid prefix (possibly just a fresh header —
            // a first-write crash can tear even the file header).
            let repaired =
                if valid == 0 { header(LOG_MAGIC) } else { raw[..valid].to_vec() };
            fs.write(&manifest.log, &repaired)?;
        }
        let log_len = match fs.read(&manifest.log) {
            Some(b) => b.len() as u64,
            None => frame::HEADER_LEN as u64,
        };

        let next_seq = manifest.next_seq + frames.len() as u64;
        let events_in_log = frames.len() as u64;
        Ok(Opened {
            log: EventLog { fs, manifest, log_len, next_seq, events_in_log },
            snapshot,
            frames,
            torn_bytes: torn,
        })
    }

    /// Sequence number the next appended event must carry.
    pub fn next_seq(&self) -> u64 {
        self.next_seq
    }

    /// Events in the current log tail (since the last compaction).
    pub fn events_in_log(&self) -> u64 {
        self.events_in_log
    }

    /// Current log file size, bytes.
    pub fn log_bytes(&self) -> u64 {
        self.log_len
    }

    pub fn manifest(&self) -> &Manifest {
        &self.manifest
    }

    /// Drop already-replayed frames the recovery pass rejected (sequence
    /// mismatch / undecodable): rewrite the log to hold exactly `frames`.
    pub fn rewrite(&mut self, frames: &[Vec<u8>]) -> io::Result<()> {
        let mut file = header(LOG_MAGIC);
        for f in frames {
            file.extend_from_slice(&encode_frame(f));
        }
        self.fs.write(&self.manifest.log, &file)?;
        self.log_len = file.len() as u64;
        self.events_in_log = frames.len() as u64;
        self.next_seq = self.manifest.next_seq + frames.len() as u64;
        Ok(())
    }

    /// Append one event payload as a frame; the payload must carry
    /// [`EventLog::next_seq`]. Durable once this returns `Ok`.
    pub fn append_payload(&mut self, payload: &[u8]) -> io::Result<()> {
        let framed = encode_frame(payload);
        self.fs.append(&self.manifest.log, &framed)?;
        self.log_len += framed.len() as u64;
        self.next_seq += 1;
        self.events_in_log += 1;
        Ok(())
    }

    /// Write a new snapshot generation and truncate the log: snapshot
    /// file + empty log first, manifest commit second, old-file cleanup
    /// last (see the module docs for the crash analysis). Compacting an
    /// already-empty tail whose snapshot exists is an idempotent no-op —
    /// generation names are derived from `next_seq`, so re-running with no
    /// new events would otherwise collide with the live generation.
    pub fn compact(&mut self, snapshot_payload: &[u8]) -> io::Result<()> {
        if self.events_in_log == 0 && self.manifest.snapshot.is_some() {
            return Ok(()); // the current snapshot already materializes everything
        }
        let seq = self.next_seq;
        let snap_name = format!("snapshot-{seq}.bin");
        let log_name = format!("wal-{seq}.log");
        let mut snap = header(SNAP_MAGIC);
        snap.extend_from_slice(&encode_frame(snapshot_payload));
        self.fs.write(&snap_name, &snap)?;
        self.fs.write(&log_name, &header(LOG_MAGIC))?;

        // Commit durably BEFORE mutating the in-memory manifest: if the
        // manifest replace fails, `self` still describes the old (and
        // still-governing) generation, so appends keep landing in a file
        // recovery will actually read — the new-generation files are
        // orphans, not data loss.
        let next = Manifest {
            version: self.manifest.version,
            next_seq: seq,
            snapshot: Some(snap_name),
            log: log_name,
        };
        self.fs.write(MANIFEST, (next.to_json().to_pretty() + "\n").as_bytes())?;
        let old = std::mem::replace(&mut self.manifest, next);

        // Remove the previous generation — never the one just committed
        // (a fresh-log compaction reuses the `wal-0.log` name).
        if let Some(old_snap) = old.snapshot {
            if self.manifest.snapshot.as_deref() != Some(old_snap.as_str()) {
                self.fs.remove(&old_snap);
            }
        }
        if old.log != self.manifest.log {
            self.fs.remove(&old.log);
        }
        self.log_len = frame::HEADER_LEN as u64;
        self.events_in_log = 0;
        Ok(())
    }
}

fn corrupt(msg: &str) -> io::Error {
    io::Error::new(io::ErrorKind::InvalidData, msg.to_string())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::persist::MemFs;

    fn open_mem(fs: &MemFs) -> Opened {
        EventLog::open(Box::new(fs.clone())).expect("open")
    }

    #[test]
    fn fresh_open_initializes_manifest_and_log() {
        let fs = MemFs::new();
        let opened = open_mem(&fs);
        assert!(opened.snapshot.is_none());
        assert!(opened.frames.is_empty());
        assert_eq!(opened.torn_bytes, 0);
        assert_eq!(opened.log.next_seq(), 0);
        assert!(fs.file(MANIFEST).is_some());
        assert_eq!(fs.file("wal-0.log").unwrap(), header(LOG_MAGIC));
    }

    #[test]
    fn appends_survive_reopen_and_torn_tail_is_repaired() {
        let fs = MemFs::new();
        let mut opened = open_mem(&fs);
        opened.log.append_payload(b"evt-0").unwrap();
        opened.log.append_payload(b"evt-1").unwrap();
        assert_eq!(opened.log.next_seq(), 2);

        // Tear the second frame mid-payload.
        let full = fs.file("wal-0.log").unwrap();
        fs.put("wal-0.log", full[..full.len() - 2].to_vec());
        let reopened = open_mem(&fs);
        assert_eq!(reopened.frames, vec![b"evt-0".to_vec()]);
        assert!(reopened.torn_bytes > 0);
        assert_eq!(reopened.log.next_seq(), 1);
        // The torn bytes were repaired away on disk.
        let repaired = fs.file("wal-0.log").unwrap();
        let (frames, valid) = scan_frames(&repaired, LOG_MAGIC);
        assert_eq!(frames.len(), 1);
        assert_eq!(valid, repaired.len());
    }

    #[test]
    fn header_torn_on_first_write_recovers_to_empty() {
        let fs = MemFs::new();
        let _ = open_mem(&fs);
        fs.put("wal-0.log", b"CAUS".to_vec()); // torn header
        let reopened = open_mem(&fs);
        assert!(reopened.frames.is_empty());
        assert_eq!(fs.file("wal-0.log").unwrap(), header(LOG_MAGIC));
        assert_eq!(reopened.log.next_seq(), 0);
    }

    #[test]
    fn compaction_switches_generation_atomically() {
        let fs = MemFs::new();
        let mut opened = open_mem(&fs);
        opened.log.append_payload(b"a").unwrap();
        opened.log.append_payload(b"b").unwrap();
        opened.log.compact(b"SNAPSHOT").unwrap();
        assert_eq!(opened.log.events_in_log(), 0);
        assert_eq!(opened.log.next_seq(), 2);
        assert!(fs.file("wal-0.log").is_none(), "old generation removed");

        let reopened = open_mem(&fs);
        assert_eq!(reopened.snapshot.as_deref(), Some(b"SNAPSHOT".as_slice()));
        assert!(reopened.frames.is_empty());
        assert_eq!(reopened.log.next_seq(), 2);
        assert_eq!(reopened.log.manifest().log, "wal-2.log");

        // Post-compaction appends land in the new log.
        let mut log = reopened.log;
        log.append_payload(b"c").unwrap();
        let reopened = open_mem(&fs);
        assert_eq!(reopened.frames, vec![b"c".to_vec()]);
        assert_eq!(reopened.log.next_seq(), 3);
    }

    #[test]
    fn compaction_with_empty_tail_is_idempotent() {
        let fs = MemFs::new();
        let mut opened = open_mem(&fs);
        opened.log.append_payload(b"a").unwrap();
        opened.log.compact(b"S1").unwrap();
        // No new events: compacting again must not eat the live snapshot.
        opened.log.compact(b"S1-again").unwrap();
        let reopened = open_mem(&fs);
        assert_eq!(reopened.snapshot.as_deref(), Some(b"S1".as_slice()));
        assert_eq!(reopened.log.next_seq(), 1);
        // A fresh log (no snapshot, no events) can compact without
        // destroying its own generation either.
        let fs2 = MemFs::new();
        let mut fresh = open_mem(&fs2);
        fresh.log.compact(b"EMPTY").unwrap();
        let reopened = open_mem(&fs2);
        assert_eq!(reopened.snapshot.as_deref(), Some(b"EMPTY".as_slice()));
        assert!(reopened.frames.is_empty());
        let mut log = reopened.log;
        log.append_payload(b"x").unwrap();
        let reopened = open_mem(&fs2);
        assert_eq!(reopened.frames, vec![b"x".to_vec()]);
    }

    #[test]
    fn crash_before_manifest_commit_keeps_old_generation() {
        let fs = MemFs::new();
        let mut opened = open_mem(&fs);
        opened.log.append_payload(b"a").unwrap();
        // Simulate the compactor crashing after writing the new snapshot +
        // log files but before the manifest replace: write them by hand.
        let mut snap = header(SNAP_MAGIC);
        snap.extend_from_slice(&encode_frame(b"HALF-DONE"));
        fs.put("snapshot-1.bin", snap);
        fs.put("wal-1.log", header(LOG_MAGIC));
        let reopened = open_mem(&fs);
        assert!(reopened.snapshot.is_none(), "old manifest still governs");
        assert_eq!(reopened.frames, vec![b"a".to_vec()]);
    }

    #[test]
    fn missing_snapshot_is_loud_corruption() {
        let fs = MemFs::new();
        let mut opened = open_mem(&fs);
        opened.log.append_payload(b"a").unwrap();
        opened.log.compact(b"S").unwrap();
        fs.remove("snapshot-1.bin");
        assert!(EventLog::open(Box::new(fs.clone())).is_err());
    }

    #[test]
    fn rewrite_drops_rejected_frames() {
        let fs = MemFs::new();
        let mut opened = open_mem(&fs);
        opened.log.append_payload(b"keep").unwrap();
        opened.log.append_payload(b"drop").unwrap();
        opened.log.rewrite(&[b"keep".to_vec()]).unwrap();
        assert_eq!(opened.log.next_seq(), 1);
        let reopened = open_mem(&fs);
        assert_eq!(reopened.frames, vec![b"keep".to_vec()]);
    }
}
