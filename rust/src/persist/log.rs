//! The append-only event log plus the manifest that names the current
//! snapshot/log generation.
//!
//! Directory layout (inside one [`PersistFs`]):
//!
//! ```text
//! MANIFEST.json        — {version, next_seq, snapshot, log}; atomic replace
//! wal-<seq>.log        — header ‖ frames (one event per frame)
//! snapshot-<seq>.bin   — header ‖ one frame holding the StateImage
//! ```
//!
//! Compaction writes the new snapshot and a fresh empty log *first*, then
//! commits by atomically replacing the manifest, then deletes the old
//! generation. A crash anywhere in that sequence leaves a readable state:
//! before the manifest commit the old generation is intact; after it the
//! new one is; stale files are garbage, not corruption.

use std::io;

use crate::persist::frame::{
    self, encode_frame, header, scan_frames, scan_frames_chained, CHAIN_SEED, LOG_MAGIC,
    SNAP_MAGIC,
};
use crate::persist::{FsyncPolicy, PersistFs};
use crate::util::Json;

/// Manifest file name.
pub const MANIFEST: &str = "MANIFEST.json";

/// The committed generation pointer.
#[derive(Clone, Debug, PartialEq)]
pub struct Manifest {
    pub version: u64,
    /// Sequence number of the first event the current log may hold (=
    /// events materialized into the snapshot).
    pub next_seq: u64,
    /// Snapshot file of this generation; `None` before the first
    /// compaction.
    pub snapshot: Option<String>,
    /// Current write-ahead log file.
    pub log: String,
}

impl Manifest {
    fn fresh() -> Manifest {
        Manifest { version: 1, next_seq: 0, snapshot: None, log: "wal-0.log".to_string() }
    }

    /// A `u64` as JSON, exactly: a plain number while `f64` still
    /// represents it losslessly (≤ 2^53), a digit string beyond that.
    /// [`Json::as_u64`] reads back both carriers without rounding.
    fn exact_u64(v: u64) -> Json {
        if v <= (1u64 << 53) {
            Json::from(v)
        } else {
            Json::Str(v.to_string())
        }
    }

    pub(crate) fn to_json(&self) -> Json {
        let snap = match &self.snapshot {
            Some(s) => Json::Str(s.clone()),
            None => Json::Null,
        };
        Json::obj()
            .set("version", Manifest::exact_u64(self.version))
            .set("next_seq", Manifest::exact_u64(self.next_seq))
            .set("snapshot", snap)
            .set("log", self.log.as_str())
    }

    pub(crate) fn from_json(j: &Json) -> Result<Manifest, String> {
        // Exact integer parse (`Json::as_u64`) — the float path (`as_f64`
        // then `as u64`) silently rounds sequence numbers past 2^53.
        let num = |k: &str| {
            j.get(k)
                .and_then(Json::as_u64)
                .ok_or_else(|| format!("manifest missing exact integer '{k}'"))
        };
        let log = j
            .get("log")
            .and_then(Json::as_str)
            .ok_or("manifest missing 'log'")?
            .to_string();
        let snapshot = match j.get("snapshot") {
            Some(Json::Str(s)) => Some(s.clone()),
            Some(Json::Null) | None => None,
            Some(other) => return Err(format!("manifest 'snapshot' malformed: {other}")),
        };
        Ok(Manifest { version: num("version")?, next_seq: num("next_seq")?, snapshot, log })
    }
}

/// What [`EventLog::open`] found on the filesystem.
pub struct Opened {
    pub log: EventLog,
    /// The committed snapshot payload, if a compaction ever ran.
    pub snapshot: Option<Vec<u8>>,
    /// Complete event frames of the log tail, in order.
    pub frames: Vec<Vec<u8>>,
    /// Torn/corrupt bytes dropped (and repaired away) from the log tail.
    pub torn_bytes: u64,
}

/// The append-only write-ahead log over a [`PersistFs`].
pub struct EventLog {
    fs: Box<dyn PersistFs>,
    manifest: Manifest,
    /// Current log file length in bytes (header included).
    log_len: u64,
    /// Sequence number of the next event to append.
    next_seq: u64,
    /// Events appended to the current log tail (resets on compaction).
    events_in_log: u64,
    /// Checksum-chain value the next appended frame must fold in (the
    /// last valid frame's stored CRC, or [`CHAIN_SEED`] on a fresh log).
    tail_crc: u32,
    /// When appended frames are forced to stable storage.
    fsync: FsyncPolicy,
    /// Appended bytes not yet covered by an fsync barrier (group commit).
    dirty: bool,
    /// Lifetime events appended through this handle (amortization stats).
    appended: u64,
    /// Lifetime fsync barriers issued on the log file.
    fsyncs: u64,
}

impl EventLog {
    /// Open (or initialize) the log inside `fs`, repairing any torn tail.
    /// The caller replays `snapshot` + `frames`, then continues appending.
    pub fn open(mut fs: Box<dyn PersistFs>) -> io::Result<Opened> {
        let manifest = match fs.read(MANIFEST) {
            Some(bytes) => {
                let text = String::from_utf8(bytes)
                    .map_err(|_| corrupt("manifest is not UTF-8"))?;
                let json = Json::parse(&text)
                    .map_err(|e| corrupt(&format!("manifest parse: {e}")))?;
                Manifest::from_json(&json).map_err(|e| corrupt(&e))?
            }
            None => {
                // Log file first, manifest second: a committed manifest
                // must never name a file that does not exist (a crash
                // between the two writes then simply re-initializes).
                let m = Manifest::fresh();
                fs.write(&m.log, &header(LOG_MAGIC))?;
                fs.write(MANIFEST, (m.to_json().to_pretty() + "\n").as_bytes())?;
                m
            }
        };

        // Snapshot: one frame behind a snapshot header. A manifest that
        // names a snapshot the filesystem lost (or that fails its CRC) is
        // unrecoverable corruption — fail loudly rather than silently
        // dropping materialized history.
        let snapshot = match &manifest.snapshot {
            None => None,
            Some(name) => {
                let bytes = fs
                    .read(name)
                    .ok_or_else(|| corrupt(&format!("snapshot '{name}' missing")))?;
                let (mut frames, _) = scan_frames(&bytes, SNAP_MAGIC);
                if frames.len() != 1 {
                    return Err(corrupt(&format!(
                        "snapshot '{name}' malformed ({} frames)",
                        frames.len()
                    )));
                }
                Some(frames.remove(0))
            }
        };

        // Log tail: keep the valid frame prefix, repair the file if a torn
        // tail (or a short/garbled header) is found. A manifest-named log
        // that is *entirely missing* is loud corruption, like a missing
        // snapshot: both init and compaction write the log file before
        // committing the manifest that names it, so no crash can legally
        // produce this state — silently starting empty would drop the
        // whole acked event tail.
        let raw = fs
            .read(&manifest.log)
            .ok_or_else(|| corrupt(&format!("log '{}' missing", manifest.log)))?;
        let (frames, valid, tail_crc) = scan_frames_chained(&raw, LOG_MAGIC);
        let torn = raw.len() as u64 - valid as u64;
        if torn > 0 || raw.is_empty() {
            // Rewrite to the valid prefix (possibly just a fresh header —
            // a first-write crash can tear even the file header).
            let repaired =
                if valid == 0 { header(LOG_MAGIC) } else { raw[..valid].to_vec() };
            fs.write(&manifest.log, &repaired)?;
        }
        let log_len = match fs.read(&manifest.log) {
            Some(b) => b.len() as u64,
            None => frame::HEADER_LEN as u64,
        };

        let next_seq = manifest.next_seq + frames.len() as u64;
        let events_in_log = frames.len() as u64;
        Ok(Opened {
            log: EventLog {
                fs,
                manifest,
                log_len,
                next_seq,
                events_in_log,
                tail_crc,
                fsync: FsyncPolicy::Never,
                dirty: false,
                appended: 0,
                fsyncs: 0,
            },
            snapshot,
            frames,
            torn_bytes: torn,
        })
    }

    /// Set when appended frames are forced to stable storage. With
    /// [`FsyncPolicy::Never`] (the default) behavior — and every byte the
    /// log writes — is identical to the pre-fsync layer.
    pub fn set_fsync(&mut self, fsync: FsyncPolicy) {
        self.fsync = fsync;
    }

    /// `(events appended, fsync barriers issued)` over this handle's
    /// lifetime — the group-commit amortization ratio's raw counters.
    pub fn fsync_stats(&self) -> (u64, u64) {
        (self.appended, self.fsyncs)
    }

    /// Are appended bytes pending an fsync barrier?
    pub fn is_dirty(&self) -> bool {
        self.dirty
    }

    /// Sequence number the next appended event must carry.
    pub fn next_seq(&self) -> u64 {
        self.next_seq
    }

    /// Events in the current log tail (since the last compaction).
    pub fn events_in_log(&self) -> u64 {
        self.events_in_log
    }

    /// Current log file size, bytes.
    pub fn log_bytes(&self) -> u64 {
        self.log_len
    }

    pub fn manifest(&self) -> &Manifest {
        &self.manifest
    }

    /// Drop already-replayed frames the recovery pass rejected (sequence
    /// mismatch / undecodable): rewrite the log to hold exactly `frames`,
    /// re-deriving the checksum chain from the seed.
    pub fn rewrite(&mut self, frames: &[Vec<u8>]) -> io::Result<()> {
        let mut file = header(LOG_MAGIC);
        let mut chain = CHAIN_SEED;
        for f in frames {
            let (bytes, next) = encode_frame(f, chain);
            file.extend_from_slice(&bytes);
            chain = next;
        }
        self.fs.write(&self.manifest.log, &file)?;
        self.log_len = file.len() as u64;
        self.events_in_log = frames.len() as u64;
        self.next_seq = self.manifest.next_seq + frames.len() as u64;
        self.tail_crc = chain;
        self.dirty = false;
        Ok(())
    }

    /// Append one event payload as a frame chained onto the log tail; the
    /// payload must carry [`EventLog::next_seq`]. Logged once this
    /// returns `Ok`; *stable* per the fsync policy — immediately under
    /// `Always`, at the next [`EventLog::sync_now`] under `GroupCommit`.
    pub fn append_payload(&mut self, payload: &[u8]) -> io::Result<()> {
        let (framed, chain) = encode_frame(payload, self.tail_crc);
        self.fs.append(&self.manifest.log, &framed)?;
        self.tail_crc = chain;
        self.log_len += framed.len() as u64;
        self.next_seq += 1;
        self.events_in_log += 1;
        self.appended += 1;
        match self.fsync {
            FsyncPolicy::Never => {}
            FsyncPolicy::Always => {
                self.fs.sync(&self.manifest.log)?;
                self.fsyncs += 1;
            }
            FsyncPolicy::GroupCommit => self.dirty = true,
        }
        Ok(())
    }

    /// Group-commit seal: one fsync barrier covering every append since
    /// the last one. No-op when nothing is pending (or under `Never`,
    /// where `dirty` is never set).
    pub fn sync_now(&mut self) -> io::Result<()> {
        if self.dirty {
            self.fs.sync(&self.manifest.log)?;
            self.fsyncs += 1;
            self.dirty = false;
        }
        Ok(())
    }

    /// Write a new snapshot generation and truncate the log: snapshot
    /// file + empty log first, manifest commit second, old-file cleanup
    /// last (see the module docs for the crash analysis). Compacting an
    /// already-empty tail whose snapshot exists is an idempotent no-op —
    /// generation names are derived from `next_seq`, so re-running with no
    /// new events would otherwise collide with the live generation.
    pub fn compact(&mut self, snapshot_payload: &[u8]) -> io::Result<()> {
        if self.events_in_log == 0 && self.manifest.snapshot.is_some() {
            return Ok(()); // the current snapshot already materializes everything
        }
        let seq = self.next_seq;
        let snap_name = format!("snapshot-{seq}.bin");
        let log_name = format!("wal-{seq}.log");
        let mut snap = header(SNAP_MAGIC);
        snap.extend_from_slice(&encode_frame(snapshot_payload, CHAIN_SEED).0);
        self.fs.write(&snap_name, &snap)?;
        self.fs.write(&log_name, &header(LOG_MAGIC))?;
        // With fsync on, the generation files must be stable before the
        // manifest names them — a manifest pointing at files the disk
        // cache lost is exactly the corruption the write order exists to
        // rule out.
        if self.fsync != FsyncPolicy::Never {
            self.fs.sync(&snap_name)?;
            self.fs.sync(&log_name)?;
        }

        // Commit durably BEFORE mutating the in-memory manifest: if the
        // manifest replace fails, `self` still describes the old (and
        // still-governing) generation, so appends keep landing in a file
        // recovery will actually read — the new-generation files are
        // orphans, not data loss.
        let next = Manifest {
            version: self.manifest.version,
            next_seq: seq,
            snapshot: Some(snap_name),
            log: log_name,
        };
        self.fs.write(MANIFEST, (next.to_json().to_pretty() + "\n").as_bytes())?;
        if self.fsync != FsyncPolicy::Never {
            self.fs.sync(MANIFEST)?;
        }
        let old = std::mem::replace(&mut self.manifest, next);

        // Remove the previous generation — never the one just committed
        // (a fresh-log compaction reuses the `wal-0.log` name).
        if let Some(old_snap) = old.snapshot {
            if self.manifest.snapshot.as_deref() != Some(old_snap.as_str()) {
                self.fs.remove(&old_snap);
            }
        }
        if old.log != self.manifest.log {
            self.fs.remove(&old.log);
        }
        self.log_len = frame::HEADER_LEN as u64;
        self.events_in_log = 0;
        self.tail_crc = CHAIN_SEED;
        // The snapshot materializes every pending event; nothing in the
        // (deleted) old tail still needs a barrier.
        self.dirty = false;
        Ok(())
    }

    /// The committed snapshot payload, re-read from the filesystem (log
    /// shipping's initial sync). `None` before the first compaction.
    pub fn snapshot_bytes(&self) -> Option<Vec<u8>> {
        let name = self.manifest.snapshot.as_deref()?;
        let bytes = self.fs.read(name)?;
        let (mut frames, _) = scan_frames(&bytes, SNAP_MAGIC);
        if frames.len() != 1 {
            return None;
        }
        Some(frames.remove(0))
    }

    /// The complete frames of the current log tail, re-read from the
    /// filesystem (log shipping's initial sync).
    pub fn tail_frames(&self) -> Vec<Vec<u8>> {
        match self.fs.read(&self.manifest.log) {
            Some(raw) => scan_frames(&raw, LOG_MAGIC).0,
            None => Vec::new(),
        }
    }
}

fn corrupt(msg: &str) -> io::Error {
    io::Error::new(io::ErrorKind::InvalidData, msg.to_string())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::persist::MemFs;

    fn open_mem(fs: &MemFs) -> Opened {
        EventLog::open(Box::new(fs.clone())).expect("open")
    }

    #[test]
    fn fresh_open_initializes_manifest_and_log() {
        let fs = MemFs::new();
        let opened = open_mem(&fs);
        assert!(opened.snapshot.is_none());
        assert!(opened.frames.is_empty());
        assert_eq!(opened.torn_bytes, 0);
        assert_eq!(opened.log.next_seq(), 0);
        assert!(fs.file(MANIFEST).is_some());
        assert_eq!(fs.file("wal-0.log").unwrap(), header(LOG_MAGIC));
    }

    #[test]
    fn appends_survive_reopen_and_torn_tail_is_repaired() {
        let fs = MemFs::new();
        let mut opened = open_mem(&fs);
        opened.log.append_payload(b"evt-0").unwrap();
        opened.log.append_payload(b"evt-1").unwrap();
        assert_eq!(opened.log.next_seq(), 2);

        // Tear the second frame mid-payload.
        let full = fs.file("wal-0.log").unwrap();
        fs.put("wal-0.log", full[..full.len() - 2].to_vec());
        let reopened = open_mem(&fs);
        assert_eq!(reopened.frames, vec![b"evt-0".to_vec()]);
        assert!(reopened.torn_bytes > 0);
        assert_eq!(reopened.log.next_seq(), 1);
        // The torn bytes were repaired away on disk.
        let repaired = fs.file("wal-0.log").unwrap();
        let (frames, valid) = scan_frames(&repaired, LOG_MAGIC);
        assert_eq!(frames.len(), 1);
        assert_eq!(valid, repaired.len());
    }

    #[test]
    fn header_torn_on_first_write_recovers_to_empty() {
        let fs = MemFs::new();
        let _ = open_mem(&fs);
        fs.put("wal-0.log", b"CAUS".to_vec()); // torn header
        let reopened = open_mem(&fs);
        assert!(reopened.frames.is_empty());
        assert_eq!(fs.file("wal-0.log").unwrap(), header(LOG_MAGIC));
        assert_eq!(reopened.log.next_seq(), 0);
    }

    #[test]
    fn compaction_switches_generation_atomically() {
        let fs = MemFs::new();
        let mut opened = open_mem(&fs);
        opened.log.append_payload(b"a").unwrap();
        opened.log.append_payload(b"b").unwrap();
        opened.log.compact(b"SNAPSHOT").unwrap();
        assert_eq!(opened.log.events_in_log(), 0);
        assert_eq!(opened.log.next_seq(), 2);
        assert!(fs.file("wal-0.log").is_none(), "old generation removed");

        let reopened = open_mem(&fs);
        assert_eq!(reopened.snapshot.as_deref(), Some(b"SNAPSHOT".as_slice()));
        assert!(reopened.frames.is_empty());
        assert_eq!(reopened.log.next_seq(), 2);
        assert_eq!(reopened.log.manifest().log, "wal-2.log");

        // Post-compaction appends land in the new log.
        let mut log = reopened.log;
        log.append_payload(b"c").unwrap();
        let reopened = open_mem(&fs);
        assert_eq!(reopened.frames, vec![b"c".to_vec()]);
        assert_eq!(reopened.log.next_seq(), 3);
    }

    #[test]
    fn compaction_with_empty_tail_is_idempotent() {
        let fs = MemFs::new();
        let mut opened = open_mem(&fs);
        opened.log.append_payload(b"a").unwrap();
        opened.log.compact(b"S1").unwrap();
        // No new events: compacting again must not eat the live snapshot.
        opened.log.compact(b"S1-again").unwrap();
        let reopened = open_mem(&fs);
        assert_eq!(reopened.snapshot.as_deref(), Some(b"S1".as_slice()));
        assert_eq!(reopened.log.next_seq(), 1);
        // A fresh log (no snapshot, no events) can compact without
        // destroying its own generation either.
        let fs2 = MemFs::new();
        let mut fresh = open_mem(&fs2);
        fresh.log.compact(b"EMPTY").unwrap();
        let reopened = open_mem(&fs2);
        assert_eq!(reopened.snapshot.as_deref(), Some(b"EMPTY".as_slice()));
        assert!(reopened.frames.is_empty());
        let mut log = reopened.log;
        log.append_payload(b"x").unwrap();
        let reopened = open_mem(&fs2);
        assert_eq!(reopened.frames, vec![b"x".to_vec()]);
    }

    #[test]
    fn crash_before_manifest_commit_keeps_old_generation() {
        let fs = MemFs::new();
        let mut opened = open_mem(&fs);
        opened.log.append_payload(b"a").unwrap();
        // Simulate the compactor crashing after writing the new snapshot +
        // log files but before the manifest replace: write them by hand.
        let mut snap = header(SNAP_MAGIC);
        snap.extend_from_slice(&encode_frame(b"HALF-DONE", CHAIN_SEED).0);
        fs.put("snapshot-1.bin", snap);
        fs.put("wal-1.log", header(LOG_MAGIC));
        let reopened = open_mem(&fs);
        assert!(reopened.snapshot.is_none(), "old manifest still governs");
        assert_eq!(reopened.frames, vec![b"a".to_vec()]);
    }

    #[test]
    fn missing_snapshot_is_loud_corruption() {
        let fs = MemFs::new();
        let mut opened = open_mem(&fs);
        opened.log.append_payload(b"a").unwrap();
        opened.log.compact(b"S").unwrap();
        fs.remove("snapshot-1.bin");
        assert!(EventLog::open(Box::new(fs.clone())).is_err());
    }

    #[test]
    fn rewrite_drops_rejected_frames() {
        let fs = MemFs::new();
        let mut opened = open_mem(&fs);
        opened.log.append_payload(b"keep").unwrap();
        opened.log.append_payload(b"drop").unwrap();
        opened.log.rewrite(&[b"keep".to_vec()]).unwrap();
        assert_eq!(opened.log.next_seq(), 1);
        let reopened = open_mem(&fs);
        assert_eq!(reopened.frames, vec![b"keep".to_vec()]);
        // The rewritten chain is valid for further appends: reopen and
        // append again, then verify the whole file scans.
        let mut log = reopened.log;
        log.append_payload(b"more").unwrap();
        let reopened = open_mem(&fs);
        assert_eq!(reopened.frames, vec![b"keep".to_vec(), b"more".to_vec()]);
        assert_eq!(reopened.torn_bytes, 0);
    }

    #[test]
    fn manifest_integers_roundtrip_exactly_past_f64() {
        // Below 2^53 both fields ride as plain JSON numbers.
        let small = Manifest {
            version: 1,
            next_seq: 123_456,
            snapshot: Some("snapshot-9.bin".into()),
            log: "wal-9.log".into(),
        };
        assert_eq!(Manifest::from_json(&small.to_json()), Ok(small.clone()));
        assert!(small.to_json().to_string().contains("\"next_seq\": 123456"));
        // Past 2^53 (u64::MAX included) they ride as digit strings and
        // still round-trip bit-exactly — the old f64 path rounded here.
        for seq in [(1u64 << 53) + 1, u64::MAX - 1, u64::MAX] {
            let big = Manifest {
                version: u64::MAX,
                next_seq: seq,
                snapshot: None,
                log: "wal-big.log".into(),
            };
            let text = big.to_json().to_pretty();
            let parsed =
                Manifest::from_json(&Json::parse(&text).unwrap()).expect("parse big");
            assert_eq!(parsed, big, "next_seq {seq} must survive the manifest");
        }
        // Legacy manifests (numbers only) still parse.
        let legacy = Json::parse(
            "{\"version\": 1, \"next_seq\": 42, \"snapshot\": null, \"log\": \"wal-42.log\"}",
        )
        .unwrap();
        assert_eq!(Manifest::from_json(&legacy).unwrap().next_seq, 42);
    }

    #[test]
    fn fsync_policies_count_barriers_and_group_commit_amortizes() {
        // Always: one barrier per append.
        let fs = MemFs::new();
        let mut opened = open_mem(&fs);
        opened.log.set_fsync(FsyncPolicy::Always);
        for i in 0..4u8 {
            opened.log.append_payload(&[i]).unwrap();
        }
        assert_eq!(opened.log.fsync_stats(), (4, 4));
        assert!(!opened.log.is_dirty());

        // GroupCommit: appends accumulate, one seal covers the batch.
        let fs = MemFs::new();
        let mut opened = open_mem(&fs);
        opened.log.set_fsync(FsyncPolicy::GroupCommit);
        for i in 0..6u8 {
            opened.log.append_payload(&[i]).unwrap();
        }
        assert!(opened.log.is_dirty());
        opened.log.sync_now().unwrap();
        opened.log.sync_now().unwrap(); // idempotent — no second barrier
        assert_eq!(opened.log.fsync_stats(), (6, 1));
        assert!(!opened.log.is_dirty());

        // Never: zero barriers, never dirty — the pre-fsync behavior.
        let fs = MemFs::new();
        let mut opened = open_mem(&fs);
        opened.log.append_payload(b"x").unwrap();
        opened.log.sync_now().unwrap();
        assert_eq!(opened.log.fsync_stats(), (1, 0));
    }

    #[test]
    fn snapshot_bytes_and_tail_frames_reread_the_generation() {
        let fs = MemFs::new();
        let mut opened = open_mem(&fs);
        assert_eq!(opened.log.snapshot_bytes(), None);
        opened.log.append_payload(b"a").unwrap();
        opened.log.compact(b"SNAP").unwrap();
        opened.log.append_payload(b"b").unwrap();
        opened.log.append_payload(b"c").unwrap();
        assert_eq!(opened.log.snapshot_bytes().as_deref(), Some(b"SNAP".as_slice()));
        assert_eq!(opened.log.tail_frames(), vec![b"b".to_vec(), b"c".to_vec()]);
    }
}
