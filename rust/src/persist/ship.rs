//! Cross-shard log shipping: stream a shard's sealed WAL frames to a
//! peer so its acknowledged unlearning obligations survive *device
//! loss*, not just a reboot.
//!
//! The source side is a [`Shipper`] owned by the shard's journal: every
//! appended event payload is staged, and at each group-commit seal the
//! staged frames are flushed through a [`ShipTransport`] as one
//! [`Shipment`]. The receive side is a [`ReplicaStore`] — an in-process
//! stand-in for the peer device's disk — holding one [`Replica`] per
//! source shard: the latest shipped snapshot plus the contiguous event
//! frames after it. [`materialize_replica`] turns a replica back into a
//! filesystem image the ordinary recovery path
//! ([`EventLog::open`](super::EventLog) → replay) can consume, which is
//! exactly how fleet failover rebuilds a dead shard on its peer.
//!
//! Transport faults are expected, not exceptional: `deliver` may fail
//! (dropped), arrive twice (duplicated), or arrive stale after newer
//! shipments (reordered). The shipper retries with bounded exponential
//! backoff measured in *flush opportunities* (deterministic — no wall
//! clock), and the replica's sequence-contiguous apply absorbs
//! duplicates and stale arrivals; a gap simply leaves the watermark
//! where it was and the next flush re-ships everything unacked.

use std::collections::BTreeMap;
use std::sync::{Arc, Mutex};

use crate::persist::frame::{
    encode_frame, header, scan_frames, scan_frames_chained, CHAIN_SEED, LOG_MAGIC, SNAP_MAGIC,
};
use crate::persist::log::MANIFEST;
use crate::persist::{Manifest, MemFs, PersistFs};
use crate::util::Json;

/// One delivery unit: a contiguous run of event frames, optionally
/// preceded by a re-base (snapshot) from a compaction or initial sync.
#[derive(Clone, Debug, PartialEq)]
pub struct Shipment {
    /// Sequence number of `frames[0]` (meaningless when `frames` is
    /// empty).
    pub first_seq: u64,
    /// Event payloads, sequence-contiguous from `first_seq`.
    pub frames: Vec<Vec<u8>>,
    /// Present when the source compacted (or on the first shipment):
    /// re-base the replica before applying `frames`.
    pub reset: Option<ShipReset>,
}

/// Re-base a replica: `snapshot` materializes every event below
/// `base_seq`.
#[derive(Clone, Debug, PartialEq)]
pub struct ShipReset {
    pub base_seq: u64,
    pub snapshot: Option<Vec<u8>>,
}

/// Where shipments go. Implementations must return `Ok` only after the
/// shipment actually reached the replica (at-least-once delivery);
/// returning the receiver's watermark lets the source drop acked frames.
/// An `Err` is a transient transport fault — the shipper retries.
pub trait ShipTransport: Send {
    /// Deliver one shipment from shard `source`; returns the replica's
    /// post-apply watermark (next sequence number it is missing).
    fn deliver(&mut self, source: usize, shipment: &Shipment) -> Result<u64, String>;
}

/// A peer-held copy of one shard's durable history: snapshot + the
/// contiguous frames after it.
#[derive(Clone, Debug, Default, PartialEq)]
pub struct Replica {
    /// Events below this are materialized in `snapshot`.
    pub base_seq: u64,
    pub snapshot: Option<Vec<u8>>,
    /// Event payloads for sequences `base_seq..base_seq + frames.len()`.
    pub frames: Vec<Vec<u8>>,
}

impl Replica {
    /// Next sequence number this replica is missing; everything below it
    /// survives loss of the source device.
    pub fn watermark(&self) -> u64 {
        self.base_seq + self.frames.len() as u64
    }

    /// Payload bytes this replica holds (snapshot + tail frames) — the
    /// quantity replica-side compaction bounds against the source's live
    /// WAL.
    pub fn bytes(&self) -> u64 {
        self.snapshot.as_ref().map_or(0, |s| s.len() as u64)
            + self.frames.iter().map(|f| f.len() as u64).sum::<u64>()
    }

    /// Idempotent, sequence-contiguous apply: duplicates are skipped,
    /// stale resets are ignored, and a gap stops the apply (the returned
    /// watermark tells the source where to resume).
    fn apply(&mut self, s: &Shipment) -> u64 {
        if let Some(r) = &s.reset {
            // Only a *forward* re-base is actionable; a duplicated or
            // stale reset must not erase frames shipped since.
            if r.base_seq > self.base_seq
                || (r.base_seq == self.base_seq && r.snapshot.is_some())
            {
                let drop = (r.base_seq.saturating_sub(self.base_seq) as usize)
                    .min(self.frames.len());
                if r.base_seq > self.base_seq + drop as u64 {
                    // Snapshot is ahead of everything we hold: adopt it
                    // outright.
                    self.frames.clear();
                } else {
                    self.frames.drain(..drop);
                }
                self.base_seq = r.base_seq;
                self.snapshot = r.snapshot.clone();
            }
        }
        for (i, payload) in s.frames.iter().enumerate() {
            let seq = s.first_seq + i as u64;
            if seq < self.watermark() {
                continue; // duplicate
            }
            if seq > self.watermark() {
                break; // gap — wait for a re-ship
            }
            self.frames.push(payload.clone());
        }
        self.watermark()
    }
}

/// Anything failover can read a peer replica back out of: the in-process
/// [`ReplicaStore`], the on-disk [`FileSpool`], or a custom transport's
/// receive side.
pub trait ReplicaSource: Send + Sync {
    /// Point-in-time copy of shard `source`'s replica (None if nothing
    /// was ever shipped).
    fn replica(&self, source: usize) -> Option<Replica>;

    /// The replica's watermark (0 if nothing was ever shipped).
    fn watermark(&self, source: usize) -> u64 {
        self.replica(source).map_or(0, |r| r.watermark())
    }
}

/// Shared in-process replica store — the "peer device disks" of a fleet.
/// Cloning shares the underlying map, so the fleet front-end and every
/// worker-held transport see the same replicas.
#[derive(Clone, Default)]
pub struct ReplicaStore {
    inner: Arc<Mutex<BTreeMap<usize, Replica>>>,
}

impl ReplicaStore {
    pub fn new() -> ReplicaStore {
        ReplicaStore::default()
    }

    /// Point-in-time copy of shard `source`'s replica.
    pub fn replica(&self, source: usize) -> Option<Replica> {
        self.inner.lock().unwrap().get(&source).cloned()
    }

    /// The replica's watermark (0 if nothing was ever shipped).
    pub fn watermark(&self, source: usize) -> u64 {
        self.inner.lock().unwrap().get(&source).map_or(0, Replica::watermark)
    }
}

impl ShipTransport for ReplicaStore {
    fn deliver(&mut self, source: usize, shipment: &Shipment) -> Result<u64, String> {
        Ok(self.inner.lock().unwrap().entry(source).or_default().apply(shipment))
    }
}

impl ReplicaSource for ReplicaStore {
    fn replica(&self, source: usize) -> Option<Replica> {
        ReplicaStore::replica(self, source)
    }

    fn watermark(&self, source: usize) -> u64 {
        ReplicaStore::watermark(self, source)
    }
}

/// Shipping state surfaced in receipts.
#[derive(Clone, Debug, PartialEq)]
pub struct ShipReceipt {
    /// Peer-acked watermark: every event below it survives source loss.
    pub shipped_seq: u64,
    /// Frames staged locally but not yet acknowledged.
    pub pending: u64,
    /// Deliveries attempted (successes and faults).
    pub attempts: u64,
    /// Deliveries that returned a transport error.
    pub faults: u64,
    /// Most recent transport error (sticky — survives a later success, so
    /// a flaky link stays diagnosable from the receipt).
    pub last_error: Option<String>,
    /// Terminal shipping error, once the retry budget is exhausted.
    pub failed: Option<String>,
}

/// Source-side shipping state machine, owned by a shard's journal.
pub struct Shipper {
    transport: Box<dyn ShipTransport>,
    source: usize,
    /// Staged `(seq, payload)` frames the peer has not acknowledged.
    pending: Vec<(u64, Vec<u8>)>,
    pending_reset: Option<ShipReset>,
    shipped_seq: u64,
    attempts: u64,
    faults: u64,
    last_error: Option<String>,
    fail_streak: u32,
    /// Flush opportunities to skip before the next retry (exponential
    /// backoff in attempt units — deterministic, no wall clock).
    skip: u64,
    retry_limit: u32,
    failed: Option<String>,
}

impl Shipper {
    /// `retry_limit` bounds *consecutive* delivery failures before
    /// shipping records a terminal error.
    pub fn new(source: usize, transport: Box<dyn ShipTransport>, retry_limit: u32) -> Shipper {
        Shipper {
            transport,
            source,
            pending: Vec::new(),
            pending_reset: None,
            shipped_seq: 0,
            attempts: 0,
            faults: 0,
            last_error: None,
            fail_streak: 0,
            skip: 0,
            retry_limit,
            failed: None,
        }
    }

    /// Initial sync: stage the journal's current generation — snapshot
    /// (if any) plus the existing log tail starting at `base_seq`.
    pub fn prime(&mut self, base_seq: u64, snapshot: Option<Vec<u8>>, frames: Vec<Vec<u8>>) {
        self.pending_reset = Some(ShipReset { base_seq, snapshot });
        self.pending =
            frames.into_iter().enumerate().map(|(i, p)| (base_seq + i as u64, p)).collect();
    }

    /// Stage one appended event for the next flush.
    pub fn stage(&mut self, seq: u64, payload: Vec<u8>) {
        self.pending.push((seq, payload));
    }

    /// The source compacted: re-base the peer at `base_seq` and drop
    /// staged frames the snapshot now materializes.
    pub fn on_compact(&mut self, base_seq: u64, snapshot: Vec<u8>) {
        self.pending_reset = Some(ShipReset { base_seq, snapshot: Some(snapshot) });
        self.pending.retain(|(s, _)| *s >= base_seq);
    }

    /// Attempt one delivery of everything staged. Returns `true` when
    /// the peer has acknowledged every staged frame. Honors the backoff
    /// schedule: after a fault, the next `2^(streak-1)` flush calls are
    /// skipped; after `retry_limit` consecutive faults shipping fails
    /// terminally (the journal itself is unaffected).
    pub fn flush(&mut self) -> bool {
        if self.failed.is_some() {
            return false;
        }
        if self.pending.is_empty() && self.pending_reset.is_none() {
            return true;
        }
        if self.skip > 0 {
            self.skip -= 1;
            return false;
        }
        let first_seq = self.pending.first().map_or(self.shipped_seq, |(s, _)| *s);
        let shipment = Shipment {
            first_seq,
            frames: self.pending.iter().map(|(_, p)| p.clone()).collect(),
            reset: self.pending_reset.clone(),
        };
        self.attempts += 1;
        match self.transport.deliver(self.source, &shipment) {
            Ok(watermark) => {
                self.fail_streak = 0;
                self.pending_reset = None;
                self.shipped_seq = self.shipped_seq.max(watermark);
                self.pending.retain(|(s, _)| *s >= watermark);
                self.pending.is_empty()
            }
            Err(e) => {
                self.faults += 1;
                self.last_error = Some(e.clone());
                self.fail_streak += 1;
                if self.fail_streak > self.retry_limit {
                    self.failed =
                        Some(format!("shipping gave up after {} faults: {e}", self.fail_streak));
                } else {
                    self.skip = 1u64 << (self.fail_streak - 1).min(16);
                }
                false
            }
        }
    }

    /// Everything staged has been acknowledged (and shipping is healthy).
    pub fn is_drained(&self) -> bool {
        self.failed.is_none() && self.pending.is_empty() && self.pending_reset.is_none()
    }

    pub fn receipt(&self) -> ShipReceipt {
        ShipReceipt {
            shipped_seq: self.shipped_seq,
            pending: self.pending.len() as u64,
            attempts: self.attempts,
            faults: self.faults,
            last_error: self.last_error.clone(),
            failed: self.failed.clone(),
        }
    }
}

/// Turn a shipped replica back into a filesystem image the standard
/// recovery path reads: `MANIFEST.json` + `snapshot-<base>.bin` +
/// `wal-<base>.log` with the frames re-framed on a fresh checksum chain.
/// This is the failover path — the peer "disk" becomes the replacement
/// shard's journal.
pub fn materialize_replica(r: &Replica) -> MemFs {
    let fs = MemFs::new();
    let log_name = format!("wal-{}.log", r.base_seq);
    let mut log = header(LOG_MAGIC);
    let mut chain = CHAIN_SEED;
    for p in &r.frames {
        let (bytes, next) = encode_frame(p, chain);
        log.extend_from_slice(&bytes);
        chain = next;
    }
    fs.put(&log_name, log);
    let snapshot = r.snapshot.as_ref().map(|payload| {
        let name = format!("snapshot-{}.bin", r.base_seq);
        let mut snap = header(SNAP_MAGIC);
        snap.extend_from_slice(&encode_frame(payload, CHAIN_SEED).0);
        fs.put(&name, snap);
        name
    });
    let m = Manifest { version: 1, next_seq: r.base_seq, snapshot, log: log_name };
    fs.put(MANIFEST, (m.to_json().to_pretty() + "\n").into_bytes());
    fs
}

/// Name of the [`FileSpool`] index file: which generation files hold each
/// source's replica. Committed atomically (`PersistFs::write`) after the
/// generation files themselves are durable, so a crash between the two
/// leaves the index pointing at the previous complete generation.
pub const SPOOL_INDEX: &str = "SPOOL.json";

fn spool_log_name(source: usize, base_seq: u64) -> String {
    format!("spool-{source}.{base_seq}.log")
}

fn spool_snap_name(source: usize, base_seq: u64) -> String {
    format!("spool-{source}.{base_seq}.snap")
}

/// One source's on-disk replica inside a [`FileSpool`].
struct SpoolEntry {
    replica: Replica,
    /// Chain value after the log file's last frame — what the next
    /// appended frame must chain onto.
    chain: u32,
    log_name: String,
    snap_name: Option<String>,
}

struct SpoolInner {
    fs: Box<dyn PersistFs>,
    entries: BTreeMap<usize, SpoolEntry>,
}

/// File-backed out-of-process [`ShipTransport`]: the peer's "disk" is a
/// real spool directory, so shipped frames survive the death of *both*
/// processes, not just the source. Each source shard gets one generation
/// pair — `spool-<src>.<base>.log` (CRC-chained frames, append-only
/// within a generation) and `spool-<src>.<base>.snap` (the re-base
/// snapshot) — plus the shared [`SPOOL_INDEX`]. A [`ShipReset`] from a
/// source compaction starts a new generation: the snapshot materializes
/// the old frames, the old generation files are deleted, and the spool's
/// footprint stays bounded by the source's live WAL.
///
/// Crash consistency mirrors the WAL itself: appends land before the
/// `sync` barrier that acks the shipment, torn tails are truncated on
/// open, and the index commit (atomic replace) is the generation switch
/// point. Any I/O error reloads the affected entry from disk before
/// reporting a transport fault, so memory never claims bytes the disk
/// lost and the shipper's retry re-ships exactly what is missing.
///
/// Clones share the underlying spool (fleet front-end + per-worker
/// transports), same as [`ReplicaStore`].
#[derive(Clone)]
pub struct FileSpool {
    inner: Arc<Mutex<SpoolInner>>,
}

impl FileSpool {
    /// Open a spool rooted at `fs`, recovering every entry the index
    /// names. Recovery is tolerant, like the WAL's: torn log tails are
    /// truncated to the last chain-valid frame, and an entry whose
    /// snapshot file is unreadable is dropped entirely (the source's
    /// next shipment re-bases it).
    pub fn open(mut fs: Box<dyn PersistFs>) -> FileSpool {
        let mut entries = BTreeMap::new();
        if let Some(bytes) = fs.read(SPOOL_INDEX) {
            if let Ok(doc) = Json::parse(&String::from_utf8_lossy(&bytes)) {
                for e in doc.get("sources").and_then(Json::as_arr).unwrap_or(&[]) {
                    let (Some(source), Some(base_seq), Some(log_name)) = (
                        e.get("source").and_then(Json::as_u64),
                        e.get("base_seq").and_then(Json::as_u64),
                        e.get("log").and_then(Json::as_str),
                    ) else {
                        continue;
                    };
                    let snap_name =
                        e.get("snapshot").and_then(Json::as_str).map(str::to_string);
                    if let Some(entry) =
                        load_spool_entry(&mut fs, base_seq, log_name.to_string(), snap_name)
                    {
                        entries.insert(source as usize, entry);
                    }
                }
            }
        }
        FileSpool { inner: Arc::new(Mutex::new(SpoolInner { fs, entries })) }
    }

    /// Sources with a spooled replica.
    pub fn sources(&self) -> Vec<usize> {
        self.inner.lock().unwrap().entries.keys().copied().collect()
    }
}

impl ReplicaSource for FileSpool {
    fn replica(&self, source: usize) -> Option<Replica> {
        self.inner.lock().unwrap().entries.get(&source).map(|e| e.replica.clone())
    }
}

impl ShipTransport for FileSpool {
    fn deliver(&mut self, source: usize, s: &Shipment) -> Result<u64, String> {
        let mut guard = self.inner.lock().unwrap();
        let inner = &mut *guard;
        let fresh = !inner.entries.contains_key(&source);
        let entry = inner.entries.entry(source).or_insert_with(|| SpoolEntry {
            replica: Replica::default(),
            chain: CHAIN_SEED,
            log_name: spool_log_name(source, 0),
            snap_name: None,
        });
        // A re-base starts a new on-disk generation; a fresh source needs
        // its first one even without a reset. Same actionability test as
        // `Replica::apply`, decided before the in-memory apply mutates.
        let rebase = fresh
            || s.reset.as_ref().is_some_and(|r| {
                r.base_seq > entry.replica.base_seq
                    || (r.base_seq == entry.replica.base_seq && r.snapshot.is_some())
            });
        let old_len = entry.replica.frames.len();
        let watermark = entry.replica.apply(s);
        // `io` carries the superseded generation names when a new one was
        // written; the entry borrow ends here so the index commit below
        // can read the whole map.
        let io: std::io::Result<Option<(String, Option<String>)>> = if rebase {
            write_spool_generation(&mut inner.fs, source, entry).map(Some)
        } else if entry.replica.frames.len() > old_len {
            append_spool_frames(&mut inner.fs, entry, old_len).map(|_| None)
        } else {
            Ok(None) // pure duplicate — disk already covers it
        };
        let result = match io {
            Err(e) => Err(e),
            Ok(None) => Ok(()),
            Ok(Some((old_log, old_snap))) => {
                match commit_spool_index(&mut inner.fs, &inner.entries) {
                    Ok(()) => {
                        // Prune the superseded generation only once the
                        // index durably points past it.
                        let (keep_log, keep_snap) = {
                            let e = &inner.entries[&source];
                            (e.log_name.clone(), e.snap_name.clone())
                        };
                        if old_log != keep_log {
                            inner.fs.remove(&old_log);
                        }
                        if let Some(n) =
                            old_snap.filter(|n| keep_snap.as_deref() != Some(n.as_str()))
                        {
                            inner.fs.remove(&n);
                        }
                        Ok(())
                    }
                    Err(e) => Err(e),
                }
            }
        };
        match result {
            Ok(()) => Ok(watermark),
            Err(e) => {
                // Re-adopt the disk's view so memory never runs ahead of
                // durable state; the shipper's retry re-ships the rest.
                reload_spool_entry(inner, source);
                Err(format!("spool I/O fault: {e}"))
            }
        }
    }
}

/// Write a full new generation (log + optional snapshot) for `source`,
/// sync both files, then retarget the entry's names. Returns the old
/// generation's names; the caller removes them only after the index
/// commit succeeds, so a crash in between never orphans the index.
fn write_spool_generation(
    fs: &mut Box<dyn PersistFs>,
    source: usize,
    entry: &mut SpoolEntry,
) -> std::io::Result<(String, Option<String>)> {
    let old = (entry.log_name.clone(), entry.snap_name.clone());
    let base = entry.replica.base_seq;
    let log_name = spool_log_name(source, base);
    let mut log = header(LOG_MAGIC);
    let mut chain = CHAIN_SEED;
    for p in &entry.replica.frames {
        let (bytes, next) = encode_frame(p, chain);
        log.extend_from_slice(&bytes);
        chain = next;
    }
    fs.write(&log_name, &log)?;
    fs.sync(&log_name)?;
    let snap_name = match &entry.replica.snapshot {
        Some(payload) => {
            let name = spool_snap_name(source, base);
            let mut snap = header(SNAP_MAGIC);
            snap.extend_from_slice(&encode_frame(payload, CHAIN_SEED).0);
            fs.write(&name, &snap)?;
            fs.sync(&name)?;
            Some(name)
        }
        None => None,
    };
    entry.log_name = log_name;
    entry.snap_name = snap_name;
    entry.chain = chain;
    Ok(old)
}

/// Append the frames past `old_len` to the entry's current log file and
/// seal them with a sync barrier (the shipment is acked only past it).
fn append_spool_frames(
    fs: &mut Box<dyn PersistFs>,
    entry: &mut SpoolEntry,
    old_len: usize,
) -> std::io::Result<()> {
    let mut buf = Vec::new();
    let mut chain = entry.chain;
    for p in &entry.replica.frames[old_len..] {
        let (bytes, next) = encode_frame(p, chain);
        buf.extend_from_slice(&bytes);
        chain = next;
    }
    fs.append(&entry.log_name, &buf)?;
    fs.sync(&entry.log_name)?;
    entry.chain = chain;
    Ok(())
}

fn commit_spool_index(
    fs: &mut Box<dyn PersistFs>,
    entries: &BTreeMap<usize, SpoolEntry>,
) -> std::io::Result<()> {
    let sources = entries
        .iter()
        .map(|(src, e)| {
            Json::obj()
                .set("source", *src)
                .set("base_seq", Json::Str(e.replica.base_seq.to_string()))
                .set("log", e.log_name.as_str())
                .set(
                    "snapshot",
                    e.snap_name.as_ref().map_or(Json::Null, |n| Json::Str(n.clone())),
                )
        })
        .collect::<Vec<_>>();
    let doc = Json::obj().set("version", 1u64).set("sources", Json::Arr(sources));
    fs.write(SPOOL_INDEX, (doc.to_pretty() + "\n").as_bytes())?;
    fs.sync(SPOOL_INDEX)
}

/// Load one entry from its generation files. `None` drops the entry
/// (snapshot unreadable — the source's next shipment re-bases).
fn load_spool_entry(
    fs: &mut Box<dyn PersistFs>,
    base_seq: u64,
    log_name: String,
    snap_name: Option<String>,
) -> Option<SpoolEntry> {
    let snapshot = match &snap_name {
        Some(name) => {
            let file = fs.read(name)?;
            let (mut frames, _) = scan_frames(&file, SNAP_MAGIC);
            if frames.is_empty() {
                return None;
            }
            Some(frames.remove(0))
        }
        None => None,
    };
    let raw = match fs.read(&log_name) {
        Some(bytes) => bytes,
        None => {
            // Log never materialized (or was lost): restart it empty so
            // later appends have a header to chain onto.
            let h = header(LOG_MAGIC);
            let _ = fs.write(&log_name, &h);
            h
        }
    };
    let (frames, valid, chain) = scan_frames_chained(&raw, LOG_MAGIC);
    if valid < raw.len() {
        // Torn tail: truncate to the chain-valid prefix so the next
        // append continues from committed frames, not garbage bytes.
        let fixed = if valid == 0 { header(LOG_MAGIC) } else { raw[..valid].to_vec() };
        let _ = fs.write(&log_name, &fixed);
    }
    Some(SpoolEntry {
        replica: Replica { base_seq, snapshot, frames },
        chain,
        log_name,
        snap_name,
    })
}

/// Re-adopt the on-disk view of `source` after an I/O fault: reload from
/// the committed index, or forget the entry if the index never learned of
/// it.
fn reload_spool_entry(inner: &mut SpoolInner, source: usize) {
    let meta = inner.fs.read(SPOOL_INDEX).and_then(|bytes| {
        let doc = Json::parse(&String::from_utf8_lossy(&bytes)).ok()?;
        doc.get("sources")?.as_arr()?.iter().find_map(|e| {
            if e.get("source").and_then(Json::as_u64) != Some(source as u64) {
                return None;
            }
            Some((
                e.get("base_seq").and_then(Json::as_u64)?,
                e.get("log").and_then(Json::as_str)?.to_string(),
                e.get("snapshot").and_then(Json::as_str).map(str::to_string),
            ))
        })
    });
    match meta.and_then(|(base, log, snap)| load_spool_entry(&mut inner.fs, base, log, snap)) {
        Some(entry) => {
            inner.entries.insert(source, entry);
        }
        None => {
            inner.entries.remove(&source);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::persist::EventLog;

    fn ship(first_seq: u64, frames: &[&[u8]], reset: Option<ShipReset>) -> Shipment {
        Shipment {
            first_seq,
            frames: frames.iter().map(|f| f.to_vec()).collect(),
            reset,
        }
    }

    #[test]
    fn replica_apply_is_idempotent_and_gap_safe() {
        let mut r = Replica::default();
        assert_eq!(r.apply(&ship(0, &[b"e0", b"e1"], None)), 2);
        // Duplicate delivery: skipped, watermark unchanged.
        assert_eq!(r.apply(&ship(0, &[b"e0", b"e1"], None)), 2);
        // Overlapping delivery: only the new frame lands.
        assert_eq!(r.apply(&ship(1, &[b"e1", b"e2"], None)), 3);
        // Gap: nothing applied, watermark tells the source to re-ship.
        assert_eq!(r.apply(&ship(5, &[b"e5"], None)), 3);
        assert_eq!(r.frames.len(), 3);
        // Stale reset (base 0, no snapshot) must not erase progress.
        assert_eq!(r.apply(&ship(0, &[], Some(ShipReset { base_seq: 0, snapshot: None }))), 3);
        assert_eq!(r.frames.len(), 3);
        // Forward reset from a compaction: snapshot absorbs a prefix.
        let w = r.apply(&ship(
            3,
            &[b"e3"],
            Some(ShipReset { base_seq: 2, snapshot: Some(b"SNAP".to_vec()) }),
        ));
        assert_eq!(w, 4);
        assert_eq!(r.base_seq, 2);
        assert_eq!(r.snapshot.as_deref(), Some(b"SNAP".as_slice()));
        assert_eq!(r.frames, vec![b"e2".to_vec(), b"e3".to_vec()]);
        // Reset ahead of everything held: adopt outright.
        let w = r.apply(&ship(
            9,
            &[],
            Some(ShipReset { base_seq: 9, snapshot: Some(b"S9".to_vec()) }),
        ));
        assert_eq!(w, 9);
        assert!(r.frames.is_empty());
    }

    /// Transport that fails on scripted attempt numbers (1-based).
    struct Flaky {
        store: ReplicaStore,
        calls: u64,
        fail_on: Vec<u64>,
    }

    impl ShipTransport for Flaky {
        fn deliver(&mut self, source: usize, s: &Shipment) -> Result<u64, String> {
            self.calls += 1;
            if self.fail_on.contains(&self.calls) {
                return Err(format!("injected fault on call {}", self.calls));
            }
            self.store.deliver(source, s)
        }
    }

    #[test]
    fn shipper_retries_with_exponential_backoff_and_converges() {
        let store = ReplicaStore::new();
        let flaky = Flaky { store: store.clone(), calls: 0, fail_on: vec![1, 2] };
        let mut sh = Shipper::new(0, Box::new(flaky), 5);
        sh.prime(0, None, vec![]);
        sh.stage(0, b"e0".to_vec());
        sh.stage(1, b"e1".to_vec());
        // Attempt 1 fails -> backoff skips 1 flush opportunity.
        assert!(!sh.flush());
        assert!(!sh.flush(), "backoff skip, no delivery attempt");
        // Attempt 2 fails -> skip 2.
        assert!(!sh.flush());
        assert!(!sh.flush());
        assert!(!sh.flush());
        // Attempt 3 succeeds and drains everything staged.
        assert!(sh.flush());
        assert!(sh.is_drained());
        let rec = sh.receipt();
        assert_eq!(rec.shipped_seq, 2);
        assert_eq!(rec.pending, 0);
        assert_eq!(rec.attempts, 3);
        assert!(rec.failed.is_none());
        assert_eq!(store.watermark(0), 2);
    }

    #[test]
    fn shipper_gives_up_after_retry_limit_without_poisoning() {
        let store = ReplicaStore::new();
        let flaky = Flaky { store: store.clone(), calls: 0, fail_on: (1..=100).collect() };
        let mut sh = Shipper::new(3, Box::new(flaky), 2);
        sh.stage(0, b"e0".to_vec());
        for _ in 0..64 {
            sh.flush();
        }
        let rec = sh.receipt();
        assert!(rec.failed.is_some(), "retry budget must exhaust");
        assert_eq!(rec.attempts, 3, "limit of 2 retries = 3 total attempts");
        assert!(!sh.is_drained());
        assert_eq!(store.watermark(3), 0);
    }

    #[test]
    fn receipt_carries_fault_diagnostics() {
        let store = ReplicaStore::new();
        let flaky = Flaky { store: store.clone(), calls: 0, fail_on: vec![1] };
        let mut sh = Shipper::new(0, Box::new(flaky), 5);
        sh.stage(0, b"e0".to_vec());
        assert!(!sh.flush()); // fault 1
        assert!(!sh.flush()); // backoff skip
        assert!(sh.flush()); // success
        let rec = sh.receipt();
        assert_eq!(rec.attempts, 2);
        assert_eq!(rec.faults, 1);
        assert_eq!(rec.last_error.as_deref(), Some("injected fault on call 1"));
        assert!(rec.failed.is_none(), "sticky last_error is diagnostic, not terminal");
    }

    #[test]
    fn file_spool_survives_reopen_and_prunes_generations_on_compact() {
        let disk = MemFs::new();
        {
            let spool = FileSpool::open(Box::new(disk.clone()));
            let mut sh = Shipper::new(1, Box::new(spool.clone()), 3);
            sh.prime(0, None, vec![]);
            sh.stage(0, b"a".to_vec());
            sh.stage(1, b"b".to_vec());
            assert!(sh.flush());
            assert!(disk.file("spool-1.0.log").is_some());
            sh.on_compact(2, b"SNAP@2".to_vec());
            sh.stage(2, b"c".to_vec());
            assert!(sh.flush());
            sh.stage(3, b"d".to_vec());
            assert!(sh.flush());
            assert_eq!(ReplicaSource::watermark(&spool, 1), 4);
        }
        // Old generation gone, new one present, index committed.
        assert!(disk.file("spool-1.0.log").is_none(), "pre-compaction generation pruned");
        assert!(disk.file("spool-1.2.log").is_some());
        assert!(disk.file("spool-1.2.snap").is_some());
        // A fresh process (the failover peer) reopens the spool from disk
        // alone and recovers the identical replica.
        let spool = FileSpool::open(Box::new(disk.clone()));
        assert_eq!(spool.sources(), vec![1]);
        let replica = ReplicaSource::replica(&spool, 1).expect("replica spooled");
        assert_eq!(replica.base_seq, 2);
        assert_eq!(replica.snapshot.as_deref(), Some(b"SNAP@2".as_slice()));
        assert_eq!(replica.frames, vec![b"c".to_vec(), b"d".to_vec()]);
        assert_eq!(replica.bytes(), 6 + 2);
        let opened =
            EventLog::open(Box::new(materialize_replica(&replica))).expect("recovery path");
        assert_eq!(opened.log.next_seq(), 4);
        assert_eq!(opened.torn_bytes, 0);
    }

    #[test]
    fn file_spool_truncates_torn_tails_and_reships_the_difference() {
        let disk = MemFs::new();
        let spool = FileSpool::open(Box::new(disk.clone()));
        let mut sh = Shipper::new(0, Box::new(spool), 3);
        sh.prime(0, None, vec![]);
        for seq in 0..4u64 {
            sh.stage(seq, format!("event-{seq}").into_bytes());
        }
        assert!(sh.flush());
        // Tear the spool log mid-frame (simulated crash of the peer).
        let mut log = disk.file("spool-0.0.log").unwrap();
        log.truncate(log.len() - 3);
        disk.put("spool-0.0.log", log);
        // Reopen: the torn frame is discarded, watermark steps back.
        let spool = FileSpool::open(Box::new(disk.clone()));
        assert_eq!(ReplicaSource::watermark(&spool, 0), 3);
        // The source re-ships from its own staging; the idempotent apply
        // dedups the survivors and restores the lost frame.
        let mut sh = Shipper::new(0, Box::new(spool.clone()), 3);
        sh.prime(0, None, (0..4).map(|s| format!("event-{s}").into_bytes()).collect());
        assert!(sh.flush());
        let replica = ReplicaSource::replica(&spool, 0).unwrap();
        assert_eq!(replica.watermark(), 4);
        assert_eq!(replica.frames[3], b"event-3");
        // And the repaired log parses cleanly end to end on disk.
        let (frames, valid, _) = crate::persist::frame::scan_frames_chained(
            &disk.file("spool-0.0.log").unwrap(),
            LOG_MAGIC,
        );
        assert_eq!(frames.len(), 4);
        assert_eq!(valid, disk.file("spool-0.0.log").unwrap().len());
    }

    #[test]
    fn file_spool_io_fault_reports_err_and_memory_tracks_disk() {
        use crate::testkit::FailpointFs;
        let mem = MemFs::new();
        let fp = FailpointFs::new(mem.clone());
        let mut spool = FileSpool::open(Box::new(fp.clone()));
        // First delivery lands (generation write + index commit).
        let ok = spool.deliver(
            0,
            &Shipment {
                first_seq: 0,
                frames: vec![b"e0".to_vec()],
                reset: Some(ShipReset { base_seq: 0, snapshot: None }),
            },
        );
        assert_eq!(ok, Ok(1));
        // Append path hits an injected fsync failure: the transport must
        // report a fault and fall back to the disk's committed view.
        fp.fail_next_syncs(1);
        let err = spool.deliver(
            0,
            &Shipment { first_seq: 1, frames: vec![b"e1".to_vec()], reset: None },
        );
        assert!(err.is_err(), "sync fault must surface as a transport fault");
        // Retry (the shipper's job) succeeds and dedups correctly.
        let ok = spool.deliver(
            0,
            &Shipment { first_seq: 1, frames: vec![b"e1".to_vec()], reset: None },
        );
        assert_eq!(ok, Ok(2));
        let replica = ReplicaSource::replica(&spool, 0).unwrap();
        assert_eq!(replica.frames, vec![b"e0".to_vec(), b"e1".to_vec()]);
        // Disk agrees with memory: reopen and compare.
        let reopened = FileSpool::open(Box::new(mem.clone()));
        assert_eq!(ReplicaSource::replica(&reopened, 0), Some(replica));
    }

    #[test]
    fn materialized_replica_reopens_through_the_standard_recovery_path() {
        // Ship a snapshot + two tail frames, then recover the replica as
        // a filesystem and open it with the ordinary EventLog.
        let store = ReplicaStore::new();
        let mut sh = Shipper::new(1, Box::new(store.clone()), 3);
        sh.prime(0, None, vec![]);
        sh.stage(0, b"a".to_vec());
        sh.stage(1, b"b".to_vec());
        assert!(sh.flush());
        sh.on_compact(2, b"SNAP@2".to_vec());
        sh.stage(2, b"c".to_vec());
        sh.stage(3, b"d".to_vec());
        assert!(sh.flush());

        let replica = store.replica(1).expect("replica exists");
        assert_eq!(replica.watermark(), 4);
        let fs = materialize_replica(&replica);
        let opened = EventLog::open(Box::new(fs)).expect("open materialized replica");
        assert_eq!(opened.snapshot.as_deref(), Some(b"SNAP@2".as_slice()));
        assert_eq!(opened.frames, vec![b"c".to_vec(), b"d".to_vec()]);
        assert_eq!(opened.log.next_seq(), 4);
        assert_eq!(opened.torn_bytes, 0);
    }
}
