//! Cross-shard log shipping: stream a shard's sealed WAL frames to a
//! peer so its acknowledged unlearning obligations survive *device
//! loss*, not just a reboot.
//!
//! The source side is a [`Shipper`] owned by the shard's journal: every
//! appended event payload is staged, and at each group-commit seal the
//! staged frames are flushed through a [`ShipTransport`] as one
//! [`Shipment`]. The receive side is a [`ReplicaStore`] — an in-process
//! stand-in for the peer device's disk — holding one [`Replica`] per
//! source shard: the latest shipped snapshot plus the contiguous event
//! frames after it. [`materialize_replica`] turns a replica back into a
//! filesystem image the ordinary recovery path
//! ([`EventLog::open`](super::EventLog) → replay) can consume, which is
//! exactly how fleet failover rebuilds a dead shard on its peer.
//!
//! Transport faults are expected, not exceptional: `deliver` may fail
//! (dropped), arrive twice (duplicated), or arrive stale after newer
//! shipments (reordered). The shipper retries with bounded exponential
//! backoff measured in *flush opportunities* (deterministic — no wall
//! clock), and the replica's sequence-contiguous apply absorbs
//! duplicates and stale arrivals; a gap simply leaves the watermark
//! where it was and the next flush re-ships everything unacked.

use std::collections::BTreeMap;
use std::sync::{Arc, Mutex};

use crate::persist::frame::{encode_frame, header, CHAIN_SEED, LOG_MAGIC, SNAP_MAGIC};
use crate::persist::log::MANIFEST;
use crate::persist::{Manifest, MemFs};

/// One delivery unit: a contiguous run of event frames, optionally
/// preceded by a re-base (snapshot) from a compaction or initial sync.
#[derive(Clone, Debug, PartialEq)]
pub struct Shipment {
    /// Sequence number of `frames[0]` (meaningless when `frames` is
    /// empty).
    pub first_seq: u64,
    /// Event payloads, sequence-contiguous from `first_seq`.
    pub frames: Vec<Vec<u8>>,
    /// Present when the source compacted (or on the first shipment):
    /// re-base the replica before applying `frames`.
    pub reset: Option<ShipReset>,
}

/// Re-base a replica: `snapshot` materializes every event below
/// `base_seq`.
#[derive(Clone, Debug, PartialEq)]
pub struct ShipReset {
    pub base_seq: u64,
    pub snapshot: Option<Vec<u8>>,
}

/// Where shipments go. Implementations must return `Ok` only after the
/// shipment actually reached the replica (at-least-once delivery);
/// returning the receiver's watermark lets the source drop acked frames.
/// An `Err` is a transient transport fault — the shipper retries.
pub trait ShipTransport: Send {
    /// Deliver one shipment from shard `source`; returns the replica's
    /// post-apply watermark (next sequence number it is missing).
    fn deliver(&mut self, source: usize, shipment: &Shipment) -> Result<u64, String>;
}

/// A peer-held copy of one shard's durable history: snapshot + the
/// contiguous frames after it.
#[derive(Clone, Debug, Default, PartialEq)]
pub struct Replica {
    /// Events below this are materialized in `snapshot`.
    pub base_seq: u64,
    pub snapshot: Option<Vec<u8>>,
    /// Event payloads for sequences `base_seq..base_seq + frames.len()`.
    pub frames: Vec<Vec<u8>>,
}

impl Replica {
    /// Next sequence number this replica is missing; everything below it
    /// survives loss of the source device.
    pub fn watermark(&self) -> u64 {
        self.base_seq + self.frames.len() as u64
    }

    /// Idempotent, sequence-contiguous apply: duplicates are skipped,
    /// stale resets are ignored, and a gap stops the apply (the returned
    /// watermark tells the source where to resume).
    fn apply(&mut self, s: &Shipment) -> u64 {
        if let Some(r) = &s.reset {
            // Only a *forward* re-base is actionable; a duplicated or
            // stale reset must not erase frames shipped since.
            if r.base_seq > self.base_seq
                || (r.base_seq == self.base_seq && r.snapshot.is_some())
            {
                let drop = (r.base_seq.saturating_sub(self.base_seq) as usize)
                    .min(self.frames.len());
                if r.base_seq > self.base_seq + drop as u64 {
                    // Snapshot is ahead of everything we hold: adopt it
                    // outright.
                    self.frames.clear();
                } else {
                    self.frames.drain(..drop);
                }
                self.base_seq = r.base_seq;
                self.snapshot = r.snapshot.clone();
            }
        }
        for (i, payload) in s.frames.iter().enumerate() {
            let seq = s.first_seq + i as u64;
            if seq < self.watermark() {
                continue; // duplicate
            }
            if seq > self.watermark() {
                break; // gap — wait for a re-ship
            }
            self.frames.push(payload.clone());
        }
        self.watermark()
    }
}

/// Shared in-process replica store — the "peer device disks" of a fleet.
/// Cloning shares the underlying map, so the fleet front-end and every
/// worker-held transport see the same replicas.
#[derive(Clone, Default)]
pub struct ReplicaStore {
    inner: Arc<Mutex<BTreeMap<usize, Replica>>>,
}

impl ReplicaStore {
    pub fn new() -> ReplicaStore {
        ReplicaStore::default()
    }

    /// Point-in-time copy of shard `source`'s replica.
    pub fn replica(&self, source: usize) -> Option<Replica> {
        self.inner.lock().unwrap().get(&source).cloned()
    }

    /// The replica's watermark (0 if nothing was ever shipped).
    pub fn watermark(&self, source: usize) -> u64 {
        self.inner.lock().unwrap().get(&source).map_or(0, Replica::watermark)
    }
}

impl ShipTransport for ReplicaStore {
    fn deliver(&mut self, source: usize, shipment: &Shipment) -> Result<u64, String> {
        Ok(self.inner.lock().unwrap().entry(source).or_default().apply(shipment))
    }
}

/// Shipping state surfaced in receipts.
#[derive(Clone, Debug, PartialEq)]
pub struct ShipReceipt {
    /// Peer-acked watermark: every event below it survives source loss.
    pub shipped_seq: u64,
    /// Frames staged locally but not yet acknowledged.
    pub pending: u64,
    /// Deliveries attempted (successes and faults).
    pub attempts: u64,
    /// Terminal shipping error, once the retry budget is exhausted.
    pub failed: Option<String>,
}

/// Source-side shipping state machine, owned by a shard's journal.
pub struct Shipper {
    transport: Box<dyn ShipTransport>,
    source: usize,
    /// Staged `(seq, payload)` frames the peer has not acknowledged.
    pending: Vec<(u64, Vec<u8>)>,
    pending_reset: Option<ShipReset>,
    shipped_seq: u64,
    attempts: u64,
    fail_streak: u32,
    /// Flush opportunities to skip before the next retry (exponential
    /// backoff in attempt units — deterministic, no wall clock).
    skip: u64,
    retry_limit: u32,
    failed: Option<String>,
}

impl Shipper {
    /// `retry_limit` bounds *consecutive* delivery failures before
    /// shipping records a terminal error.
    pub fn new(source: usize, transport: Box<dyn ShipTransport>, retry_limit: u32) -> Shipper {
        Shipper {
            transport,
            source,
            pending: Vec::new(),
            pending_reset: None,
            shipped_seq: 0,
            attempts: 0,
            fail_streak: 0,
            skip: 0,
            retry_limit,
            failed: None,
        }
    }

    /// Initial sync: stage the journal's current generation — snapshot
    /// (if any) plus the existing log tail starting at `base_seq`.
    pub fn prime(&mut self, base_seq: u64, snapshot: Option<Vec<u8>>, frames: Vec<Vec<u8>>) {
        self.pending_reset = Some(ShipReset { base_seq, snapshot });
        self.pending =
            frames.into_iter().enumerate().map(|(i, p)| (base_seq + i as u64, p)).collect();
    }

    /// Stage one appended event for the next flush.
    pub fn stage(&mut self, seq: u64, payload: Vec<u8>) {
        self.pending.push((seq, payload));
    }

    /// The source compacted: re-base the peer at `base_seq` and drop
    /// staged frames the snapshot now materializes.
    pub fn on_compact(&mut self, base_seq: u64, snapshot: Vec<u8>) {
        self.pending_reset = Some(ShipReset { base_seq, snapshot: Some(snapshot) });
        self.pending.retain(|(s, _)| *s >= base_seq);
    }

    /// Attempt one delivery of everything staged. Returns `true` when
    /// the peer has acknowledged every staged frame. Honors the backoff
    /// schedule: after a fault, the next `2^(streak-1)` flush calls are
    /// skipped; after `retry_limit` consecutive faults shipping fails
    /// terminally (the journal itself is unaffected).
    pub fn flush(&mut self) -> bool {
        if self.failed.is_some() {
            return false;
        }
        if self.pending.is_empty() && self.pending_reset.is_none() {
            return true;
        }
        if self.skip > 0 {
            self.skip -= 1;
            return false;
        }
        let first_seq = self.pending.first().map_or(self.shipped_seq, |(s, _)| *s);
        let shipment = Shipment {
            first_seq,
            frames: self.pending.iter().map(|(_, p)| p.clone()).collect(),
            reset: self.pending_reset.clone(),
        };
        self.attempts += 1;
        match self.transport.deliver(self.source, &shipment) {
            Ok(watermark) => {
                self.fail_streak = 0;
                self.pending_reset = None;
                self.shipped_seq = self.shipped_seq.max(watermark);
                self.pending.retain(|(s, _)| *s >= watermark);
                self.pending.is_empty()
            }
            Err(e) => {
                self.fail_streak += 1;
                if self.fail_streak > self.retry_limit {
                    self.failed =
                        Some(format!("shipping gave up after {} faults: {e}", self.fail_streak));
                } else {
                    self.skip = 1u64 << (self.fail_streak - 1).min(16);
                }
                false
            }
        }
    }

    /// Everything staged has been acknowledged (and shipping is healthy).
    pub fn is_drained(&self) -> bool {
        self.failed.is_none() && self.pending.is_empty() && self.pending_reset.is_none()
    }

    pub fn receipt(&self) -> ShipReceipt {
        ShipReceipt {
            shipped_seq: self.shipped_seq,
            pending: self.pending.len() as u64,
            attempts: self.attempts,
            failed: self.failed.clone(),
        }
    }
}

/// Turn a shipped replica back into a filesystem image the standard
/// recovery path reads: `MANIFEST.json` + `snapshot-<base>.bin` +
/// `wal-<base>.log` with the frames re-framed on a fresh checksum chain.
/// This is the failover path — the peer "disk" becomes the replacement
/// shard's journal.
pub fn materialize_replica(r: &Replica) -> MemFs {
    let fs = MemFs::new();
    let log_name = format!("wal-{}.log", r.base_seq);
    let mut log = header(LOG_MAGIC);
    let mut chain = CHAIN_SEED;
    for p in &r.frames {
        let (bytes, next) = encode_frame(p, chain);
        log.extend_from_slice(&bytes);
        chain = next;
    }
    fs.put(&log_name, log);
    let snapshot = r.snapshot.as_ref().map(|payload| {
        let name = format!("snapshot-{}.bin", r.base_seq);
        let mut snap = header(SNAP_MAGIC);
        snap.extend_from_slice(&encode_frame(payload, CHAIN_SEED).0);
        fs.put(&name, snap);
        name
    });
    let m = Manifest { version: 1, next_seq: r.base_seq, snapshot, log: log_name };
    fs.put(MANIFEST, (m.to_json().to_pretty() + "\n").into_bytes());
    fs
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::persist::EventLog;

    fn ship(first_seq: u64, frames: &[&[u8]], reset: Option<ShipReset>) -> Shipment {
        Shipment {
            first_seq,
            frames: frames.iter().map(|f| f.to_vec()).collect(),
            reset,
        }
    }

    #[test]
    fn replica_apply_is_idempotent_and_gap_safe() {
        let mut r = Replica::default();
        assert_eq!(r.apply(&ship(0, &[b"e0", b"e1"], None)), 2);
        // Duplicate delivery: skipped, watermark unchanged.
        assert_eq!(r.apply(&ship(0, &[b"e0", b"e1"], None)), 2);
        // Overlapping delivery: only the new frame lands.
        assert_eq!(r.apply(&ship(1, &[b"e1", b"e2"], None)), 3);
        // Gap: nothing applied, watermark tells the source to re-ship.
        assert_eq!(r.apply(&ship(5, &[b"e5"], None)), 3);
        assert_eq!(r.frames.len(), 3);
        // Stale reset (base 0, no snapshot) must not erase progress.
        assert_eq!(r.apply(&ship(0, &[], Some(ShipReset { base_seq: 0, snapshot: None }))), 3);
        assert_eq!(r.frames.len(), 3);
        // Forward reset from a compaction: snapshot absorbs a prefix.
        let w = r.apply(&ship(
            3,
            &[b"e3"],
            Some(ShipReset { base_seq: 2, snapshot: Some(b"SNAP".to_vec()) }),
        ));
        assert_eq!(w, 4);
        assert_eq!(r.base_seq, 2);
        assert_eq!(r.snapshot.as_deref(), Some(b"SNAP".as_slice()));
        assert_eq!(r.frames, vec![b"e2".to_vec(), b"e3".to_vec()]);
        // Reset ahead of everything held: adopt outright.
        let w = r.apply(&ship(
            9,
            &[],
            Some(ShipReset { base_seq: 9, snapshot: Some(b"S9".to_vec()) }),
        ));
        assert_eq!(w, 9);
        assert!(r.frames.is_empty());
    }

    /// Transport that fails on scripted attempt numbers (1-based).
    struct Flaky {
        store: ReplicaStore,
        calls: u64,
        fail_on: Vec<u64>,
    }

    impl ShipTransport for Flaky {
        fn deliver(&mut self, source: usize, s: &Shipment) -> Result<u64, String> {
            self.calls += 1;
            if self.fail_on.contains(&self.calls) {
                return Err(format!("injected fault on call {}", self.calls));
            }
            self.store.deliver(source, s)
        }
    }

    #[test]
    fn shipper_retries_with_exponential_backoff_and_converges() {
        let store = ReplicaStore::new();
        let flaky = Flaky { store: store.clone(), calls: 0, fail_on: vec![1, 2] };
        let mut sh = Shipper::new(0, Box::new(flaky), 5);
        sh.prime(0, None, vec![]);
        sh.stage(0, b"e0".to_vec());
        sh.stage(1, b"e1".to_vec());
        // Attempt 1 fails -> backoff skips 1 flush opportunity.
        assert!(!sh.flush());
        assert!(!sh.flush(), "backoff skip, no delivery attempt");
        // Attempt 2 fails -> skip 2.
        assert!(!sh.flush());
        assert!(!sh.flush());
        assert!(!sh.flush());
        // Attempt 3 succeeds and drains everything staged.
        assert!(sh.flush());
        assert!(sh.is_drained());
        let rec = sh.receipt();
        assert_eq!(rec.shipped_seq, 2);
        assert_eq!(rec.pending, 0);
        assert_eq!(rec.attempts, 3);
        assert!(rec.failed.is_none());
        assert_eq!(store.watermark(0), 2);
    }

    #[test]
    fn shipper_gives_up_after_retry_limit_without_poisoning() {
        let store = ReplicaStore::new();
        let flaky = Flaky { store: store.clone(), calls: 0, fail_on: (1..=100).collect() };
        let mut sh = Shipper::new(3, Box::new(flaky), 2);
        sh.stage(0, b"e0".to_vec());
        for _ in 0..64 {
            sh.flush();
        }
        let rec = sh.receipt();
        assert!(rec.failed.is_some(), "retry budget must exhaust");
        assert_eq!(rec.attempts, 3, "limit of 2 retries = 3 total attempts");
        assert!(!sh.is_drained());
        assert_eq!(store.watermark(3), 0);
    }

    #[test]
    fn materialized_replica_reopens_through_the_standard_recovery_path() {
        // Ship a snapshot + two tail frames, then recover the replica as
        // a filesystem and open it with the ordinary EventLog.
        let store = ReplicaStore::new();
        let mut sh = Shipper::new(1, Box::new(store.clone()), 3);
        sh.prime(0, None, vec![]);
        sh.stage(0, b"a".to_vec());
        sh.stage(1, b"b".to_vec());
        assert!(sh.flush());
        sh.on_compact(2, b"SNAP@2".to_vec());
        sh.stage(2, b"c".to_vec());
        sh.stage(3, b"d".to_vec());
        assert!(sh.flush());

        let replica = store.replica(1).expect("replica exists");
        assert_eq!(replica.watermark(), 4);
        let fs = materialize_replica(&replica);
        let opened = EventLog::open(Box::new(fs)).expect("open materialized replica");
        assert_eq!(opened.snapshot.as_deref(), Some(b"SNAP@2".as_slice()));
        assert_eq!(opened.frames, vec![b"c".to_vec(), b"d".to_vec()]);
        assert_eq!(opened.log.next_seq(), 4);
        assert_eq!(opened.torn_bytes, 0);
    }
}
