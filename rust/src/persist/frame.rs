//! CRC-framed, length-prefixed binary framing for the write-ahead log.
//!
//! A persisted file is `magic (8 bytes) ‖ version (u32 LE) ‖ frames…`, and
//! every frame is `len (u32 LE) ‖ crc32(payload) (u32 LE) ‖ payload`. The
//! reader stops at the first incomplete or CRC-failing frame, so a crash
//! that tears a write anywhere — header bytes, length prefix, mid-payload —
//! degrades to "the log ends at the last fully committed frame". That is
//! the whole crash-consistency story at this layer: a frame is either
//! entirely in the log or not in it at all, and
//! [`scan_frames`] is a pure function of the byte prefix, so truncating
//! the file at *any* byte offset yields the same frames as truncating at
//! the previous frame boundary (property-tested below and in
//! `tests/durability.rs`).

use std::sync::OnceLock;

/// Magic prefix of a write-ahead log file.
pub const LOG_MAGIC: &[u8; 8] = b"CAUSEWAL";

/// Magic prefix of a state-snapshot file.
pub const SNAP_MAGIC: &[u8; 8] = b"CAUSESNP";

/// On-disk format version (bumped on incompatible layout changes).
pub const FORMAT_VERSION: u32 = 1;

/// Bytes of `magic ‖ version` at the start of every persisted file.
pub const HEADER_LEN: usize = 12;

/// Upper bound on a single frame's payload — corrupt length prefixes must
/// not allocate unbounded memory.
const MAX_FRAME_LEN: u32 = 256 * 1024 * 1024;

/// CRC-32 (IEEE 802.3, reflected) over `bytes`.
pub fn crc32(bytes: &[u8]) -> u32 {
    static TABLE: OnceLock<[u32; 256]> = OnceLock::new();
    let table = TABLE.get_or_init(|| {
        let mut t = [0u32; 256];
        for (i, e) in t.iter_mut().enumerate() {
            let mut c = i as u32;
            for _ in 0..8 {
                c = if c & 1 != 0 { 0xedb8_8320 ^ (c >> 1) } else { c >> 1 };
            }
            *e = c;
        }
        t
    });
    let mut c = !0u32;
    for &b in bytes {
        c = table[((c ^ b as u32) & 0xff) as usize] ^ (c >> 8);
    }
    !c
}

/// File header for the given magic.
pub fn header(magic: &[u8; 8]) -> Vec<u8> {
    let mut h = magic.to_vec();
    h.extend_from_slice(&FORMAT_VERSION.to_le_bytes());
    h
}

/// Does `file` start with a valid header for `magic`?
pub fn header_ok(file: &[u8], magic: &[u8; 8]) -> bool {
    file.len() >= HEADER_LEN
        && &file[..8] == magic
        && file[8..12] == FORMAT_VERSION.to_le_bytes()
}

/// Wrap a payload into one frame.
pub fn encode_frame(payload: &[u8]) -> Vec<u8> {
    let mut out = Vec::with_capacity(8 + payload.len());
    out.extend_from_slice(&(payload.len() as u32).to_le_bytes());
    out.extend_from_slice(&crc32(payload).to_le_bytes());
    out.extend_from_slice(payload);
    out
}

fn read_u32(file: &[u8], at: usize) -> Option<u32> {
    let b = file.get(at..at + 4)?;
    Some(u32::from_le_bytes([b[0], b[1], b[2], b[3]]))
}

/// Scan every complete frame of `file` (header included). Returns the
/// frame payloads plus the byte length of the valid prefix (header +
/// complete frames); anything beyond it is a torn tail to discard. A file
/// whose header itself is torn or mismatched yields `(vec![], 0)`.
pub fn scan_frames(file: &[u8], magic: &[u8; 8]) -> (Vec<Vec<u8>>, usize) {
    if !header_ok(file, magic) {
        return (Vec::new(), 0);
    }
    let mut frames = Vec::new();
    let mut pos = HEADER_LEN;
    loop {
        let Some(len) = read_u32(file, pos) else { break };
        if len > MAX_FRAME_LEN {
            break;
        }
        let Some(crc) = read_u32(file, pos + 4) else { break };
        let end = pos + 8 + len as usize;
        let Some(payload) = file.get(pos + 8..end) else { break };
        if crc32(payload) != crc {
            break;
        }
        frames.push(payload.to_vec());
        pos = end;
    }
    (frames, pos)
}

/// End offsets (within `file`) of every complete frame — the legal crash
/// points the kill-point harness enumerates.
pub fn frame_bounds(file: &[u8], magic: &[u8; 8]) -> Vec<usize> {
    if !header_ok(file, magic) {
        return Vec::new();
    }
    let mut bounds = Vec::new();
    let mut pos = HEADER_LEN;
    while let (Some(len), Some(crc)) = (read_u32(file, pos), read_u32(file, pos + 4)) {
        if len > MAX_FRAME_LEN {
            break;
        }
        let end = pos + 8 + len as usize;
        match file.get(pos + 8..end) {
            Some(payload) if crc32(payload) == crc => {
                bounds.push(end);
                pos = end;
            }
            _ => break,
        }
    }
    bounds
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::prng::Rng;
    use crate::testkit::forall;

    #[test]
    fn crc32_matches_known_vectors() {
        // Standard IEEE test vectors.
        assert_eq!(crc32(b""), 0);
        assert_eq!(crc32(b"123456789"), 0xcbf4_3926);
        assert_eq!(crc32(b"The quick brown fox jumps over the lazy dog"), 0x414f_a339);
    }

    #[test]
    fn frames_roundtrip() {
        let mut file = header(LOG_MAGIC);
        let payloads: Vec<Vec<u8>> =
            vec![vec![], vec![7], vec![1, 2, 3], (0..=255u8).collect()];
        for p in &payloads {
            file.extend_from_slice(&encode_frame(p));
        }
        let (frames, valid) = scan_frames(&file, LOG_MAGIC);
        assert_eq!(frames, payloads);
        assert_eq!(valid, file.len());
        assert_eq!(frame_bounds(&file, LOG_MAGIC).len(), payloads.len());
        assert_eq!(*frame_bounds(&file, LOG_MAGIC).last().unwrap(), file.len());
    }

    #[test]
    fn wrong_magic_or_version_is_empty() {
        let file = header(SNAP_MAGIC);
        assert_eq!(scan_frames(&file, LOG_MAGIC), (vec![], 0));
        let mut bad = header(LOG_MAGIC);
        bad[9] ^= 1; // corrupt the version
        assert_eq!(scan_frames(&bad, LOG_MAGIC), (vec![], 0));
        assert_eq!(scan_frames(b"CA", LOG_MAGIC), (vec![], 0));
    }

    #[test]
    fn corrupt_byte_drops_tail_not_prefix() {
        let mut file = header(LOG_MAGIC);
        file.extend_from_slice(&encode_frame(b"first"));
        let second_at = file.len();
        file.extend_from_slice(&encode_frame(b"second"));
        // Flip a payload byte of the second frame: frame 1 survives.
        let mut torn = file.clone();
        torn[second_at + 9] ^= 0xff;
        let (frames, valid) = scan_frames(&torn, LOG_MAGIC);
        assert_eq!(frames, vec![b"first".to_vec()]);
        assert_eq!(valid, second_at);
    }

    #[test]
    fn insane_length_prefix_is_torn_tail() {
        let mut file = header(LOG_MAGIC);
        file.extend_from_slice(&encode_frame(b"ok"));
        let cut = file.len();
        file.extend_from_slice(&u32::MAX.to_le_bytes()); // absurd length
        file.extend_from_slice(&[0; 32]);
        let (frames, valid) = scan_frames(&file, LOG_MAGIC);
        assert_eq!(frames.len(), 1);
        assert_eq!(valid, cut);
    }

    /// The framing invariant the whole durability design rests on:
    /// truncating the file at ANY byte offset yields exactly the frames of
    /// the last complete boundary at or before it — never a torn frame,
    /// never a lost committed one.
    #[test]
    fn prop_truncation_at_every_byte_is_boundary_equivalent() {
        forall(
            0xF4A3E5,
            25,
            |rng: &mut Rng, size| {
                let n = 1 + (6.0 * size) as usize;
                (0..n)
                    .map(|_| {
                        let len = rng.range(0, 40);
                        (0..len).map(|_| rng.below(256) as u8).collect::<Vec<u8>>()
                    })
                    .collect::<Vec<_>>()
            },
            |payloads| {
                let mut file = header(LOG_MAGIC);
                let mut bounds = vec![HEADER_LEN];
                for p in payloads {
                    file.extend_from_slice(&encode_frame(p));
                    bounds.push(file.len());
                }
                for cut in 0..=file.len() {
                    let (frames, valid) = scan_frames(&file[..cut], LOG_MAGIC);
                    let expect_k = if cut < HEADER_LEN {
                        0
                    } else {
                        bounds.iter().filter(|b| **b <= cut).count() - 1
                    };
                    if frames.len() != expect_k {
                        return Err(format!(
                            "cut {cut}: {} frames, expected {expect_k}",
                            frames.len()
                        ));
                    }
                    if frames.as_slice() != &payloads[..expect_k] {
                        return Err(format!("cut {cut}: frame bytes diverged"));
                    }
                    if cut >= HEADER_LEN && valid != bounds[expect_k] {
                        return Err(format!(
                            "cut {cut}: valid prefix {valid} != boundary {}",
                            bounds[expect_k]
                        ));
                    }
                }
                Ok(())
            },
        );
    }
}
