//! CRC-framed, length-prefixed binary framing for the write-ahead log.
//!
//! A persisted file is `magic (8 bytes) ‖ version (u32 LE) ‖ frames…`, and
//! every frame is `len (u32 LE) ‖ chain-crc (u32 LE) ‖ payload`. The
//! checksum is **chained**: frame `i` stores
//! `crc32(crc_{i-1} (LE bytes) ‖ payload_i)` with `crc_{-1} =`
//! [`CHAIN_SEED`], so each frame's checksum commits to the entire frame
//! history before it. A per-frame CRC alone proves each frame is
//! internally intact but cannot see a *splice* — a log whose tail was
//! truncated and rewritten with different (individually well-formed)
//! frames. With chaining, the first rewritten frame fails its chain check
//! unless the writer knew the exact checksum of every frame before it.
//!
//! The reader stops at the first incomplete or chain-failing frame, so a
//! crash that tears a write anywhere — header bytes, length prefix,
//! mid-payload — degrades to "the log ends at the last fully committed
//! frame". That is the whole crash-consistency story at this layer: a
//! frame is either entirely in the log or not in it at all, and
//! [`scan_frames`] is a pure function of the byte prefix, so truncating
//! the file at *any* byte offset yields the same frames as truncating at
//! the previous frame boundary (property-tested below and in
//! `tests/durability.rs`).

use std::sync::OnceLock;

/// Magic prefix of a write-ahead log file.
pub const LOG_MAGIC: &[u8; 8] = b"CAUSEWAL";

/// Magic prefix of a state-snapshot file.
pub const SNAP_MAGIC: &[u8; 8] = b"CAUSESNP";

/// On-disk format version (bumped on incompatible layout changes).
/// Version 2 introduced checksum chaining; a v1 file fails `header_ok`
/// and reads as empty rather than being mis-verified.
pub const FORMAT_VERSION: u32 = 2;

/// Bytes of `magic ‖ version` at the start of every persisted file.
pub const HEADER_LEN: usize = 12;

/// Chain value "before the first frame" — the seed every file's checksum
/// chain starts from, and the value [`EventLog`](super::EventLog) resets
/// to when it opens a fresh generation.
pub const CHAIN_SEED: u32 = 0;

/// Upper bound on a single frame's payload — corrupt length prefixes must
/// not allocate unbounded memory.
const MAX_FRAME_LEN: u32 = 256 * 1024 * 1024;

fn crc_table() -> &'static [u32; 256] {
    static TABLE: OnceLock<[u32; 256]> = OnceLock::new();
    TABLE.get_or_init(|| {
        let mut t = [0u32; 256];
        for (i, e) in t.iter_mut().enumerate() {
            let mut c = i as u32;
            for _ in 0..8 {
                c = if c & 1 != 0 { 0xedb8_8320 ^ (c >> 1) } else { c >> 1 };
            }
            *e = c;
        }
        t
    })
}

/// CRC-32 (IEEE 802.3, reflected) over `bytes`.
pub fn crc32(bytes: &[u8]) -> u32 {
    let table = crc_table();
    let mut c = !0u32;
    for &b in bytes {
        c = table[((c ^ b as u32) & 0xff) as usize] ^ (c >> 8);
    }
    !c
}

/// Chained frame checksum: CRC-32 over `prev (4 LE bytes) ‖ payload`.
/// Folding the previous frame's checksum into this one makes every
/// checksum a commitment to the whole log prefix.
pub fn chain_crc(prev: u32, payload: &[u8]) -> u32 {
    let table = crc_table();
    let mut c = !0u32;
    for &b in prev.to_le_bytes().iter().chain(payload.iter()) {
        c = table[((c ^ b as u32) & 0xff) as usize] ^ (c >> 8);
    }
    !c
}

/// File header for the given magic.
pub fn header(magic: &[u8; 8]) -> Vec<u8> {
    let mut h = magic.to_vec();
    h.extend_from_slice(&FORMAT_VERSION.to_le_bytes());
    h
}

/// Does `file` start with a valid header for `magic`?
pub fn header_ok(file: &[u8], magic: &[u8; 8]) -> bool {
    file.len() >= HEADER_LEN
        && &file[..8] == magic
        && file[8..12] == FORMAT_VERSION.to_le_bytes()
}

/// Wrap a payload into one frame, chained onto `prev` (the previous
/// frame's checksum, or [`CHAIN_SEED`] at the start of a file). Returns
/// the encoded frame and the new chain value to thread into the next
/// frame.
pub fn encode_frame(payload: &[u8], prev: u32) -> (Vec<u8>, u32) {
    let crc = chain_crc(prev, payload);
    let mut out = Vec::with_capacity(8 + payload.len());
    out.extend_from_slice(&(payload.len() as u32).to_le_bytes());
    out.extend_from_slice(&crc.to_le_bytes());
    out.extend_from_slice(payload);
    (out, crc)
}

fn read_u32(file: &[u8], at: usize) -> Option<u32> {
    let b = file.get(at..at + 4)?;
    Some(u32::from_le_bytes([b[0], b[1], b[2], b[3]]))
}

/// Scan every complete frame of `file` (header included), verifying the
/// checksum chain. Returns the frame payloads, the byte length of the
/// valid prefix (header + complete frames), and the chain value after the
/// last valid frame (what the next appended frame must chain onto);
/// anything beyond the valid prefix is a torn tail to discard. A file
/// whose header itself is torn or mismatched yields `(vec![], 0, seed)`.
pub fn scan_frames_chained(file: &[u8], magic: &[u8; 8]) -> (Vec<Vec<u8>>, usize, u32) {
    let mut chain = CHAIN_SEED;
    if !header_ok(file, magic) {
        return (Vec::new(), 0, chain);
    }
    let mut frames = Vec::new();
    let mut pos = HEADER_LEN;
    loop {
        let Some(len) = read_u32(file, pos) else { break };
        if len > MAX_FRAME_LEN {
            break;
        }
        let Some(crc) = read_u32(file, pos + 4) else { break };
        let end = pos + 8 + len as usize;
        let Some(payload) = file.get(pos + 8..end) else { break };
        if chain_crc(chain, payload) != crc {
            break;
        }
        chain = crc;
        frames.push(payload.to_vec());
        pos = end;
    }
    (frames, pos, chain)
}

/// [`scan_frames_chained`] without the final chain value, for callers
/// that only replay.
pub fn scan_frames(file: &[u8], magic: &[u8; 8]) -> (Vec<Vec<u8>>, usize) {
    let (frames, valid, _) = scan_frames_chained(file, magic);
    (frames, valid)
}

/// End offsets (within `file`) of every complete chain-valid frame — the
/// legal crash points the kill-point harness enumerates.
pub fn frame_bounds(file: &[u8], magic: &[u8; 8]) -> Vec<usize> {
    if !header_ok(file, magic) {
        return Vec::new();
    }
    let mut bounds = Vec::new();
    let mut chain = CHAIN_SEED;
    let mut pos = HEADER_LEN;
    while let (Some(len), Some(crc)) = (read_u32(file, pos), read_u32(file, pos + 4)) {
        if len > MAX_FRAME_LEN {
            break;
        }
        let end = pos + 8 + len as usize;
        match file.get(pos + 8..end) {
            Some(payload) if chain_crc(chain, payload) == crc => {
                chain = crc;
                bounds.push(end);
                pos = end;
            }
            _ => break,
        }
    }
    bounds
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::prng::Rng;
    use crate::testkit::forall;

    /// Build a well-formed file: header + chained frames.
    fn frame_file(magic: &[u8; 8], payloads: &[Vec<u8>]) -> Vec<u8> {
        let mut file = header(magic);
        let mut chain = CHAIN_SEED;
        for p in payloads {
            let (bytes, next) = encode_frame(p, chain);
            file.extend_from_slice(&bytes);
            chain = next;
        }
        file
    }

    #[test]
    fn crc32_matches_known_vectors() {
        // Standard IEEE test vectors.
        assert_eq!(crc32(b""), 0);
        assert_eq!(crc32(b"123456789"), 0xcbf4_3926);
        assert_eq!(crc32(b"The quick brown fox jumps over the lazy dog"), 0x414f_a339);
        // chain_crc is crc32 over the concatenation — pin it to crc32.
        let mut cat = 7u32.to_le_bytes().to_vec();
        cat.extend_from_slice(b"payload");
        assert_eq!(chain_crc(7, b"payload"), crc32(&cat));
    }

    #[test]
    fn frames_roundtrip() {
        let payloads: Vec<Vec<u8>> =
            vec![vec![], vec![7], vec![1, 2, 3], (0..=255u8).collect()];
        let file = frame_file(LOG_MAGIC, &payloads);
        let (frames, valid, chain) = scan_frames_chained(&file, LOG_MAGIC);
        assert_eq!(frames, payloads);
        assert_eq!(valid, file.len());
        // The returned chain is the last frame's stored checksum.
        let last_at = frame_bounds(&file, LOG_MAGIC)[payloads.len() - 2];
        assert_eq!(chain, read_u32(&file, last_at + 4).unwrap());
        assert_eq!(frame_bounds(&file, LOG_MAGIC).len(), payloads.len());
        assert_eq!(*frame_bounds(&file, LOG_MAGIC).last().unwrap(), file.len());
    }

    #[test]
    fn wrong_magic_or_version_is_empty() {
        let file = header(SNAP_MAGIC);
        assert_eq!(scan_frames(&file, LOG_MAGIC), (vec![], 0));
        let mut bad = header(LOG_MAGIC);
        bad[9] ^= 1; // corrupt the version
        assert_eq!(scan_frames(&bad, LOG_MAGIC), (vec![], 0));
        assert_eq!(scan_frames(b"CA", LOG_MAGIC), (vec![], 0));
    }

    #[test]
    fn corrupt_byte_drops_tail_not_prefix() {
        let first = frame_file(LOG_MAGIC, &[b"first".to_vec()]);
        let second_at = first.len();
        let file = frame_file(LOG_MAGIC, &[b"first".to_vec(), b"second".to_vec()]);
        // Flip a payload byte of the second frame: frame 1 survives.
        let mut torn = file.clone();
        torn[second_at + 9] ^= 0xff;
        let (frames, valid) = scan_frames(&torn, LOG_MAGIC);
        assert_eq!(frames, vec![b"first".to_vec()]);
        assert_eq!(valid, second_at);
    }

    #[test]
    fn insane_length_prefix_is_torn_tail() {
        let mut file = frame_file(LOG_MAGIC, &[b"ok".to_vec()]);
        let cut = file.len();
        file.extend_from_slice(&u32::MAX.to_le_bytes()); // absurd length
        file.extend_from_slice(&[0; 32]);
        let (frames, valid) = scan_frames(&file, LOG_MAGIC);
        assert_eq!(frames.len(), 1);
        assert_eq!(valid, cut);
    }

    /// The attack a per-frame CRC cannot see: truncate the log at a
    /// boundary and rewrite the tail with different, individually
    /// well-formed frames. The chain makes the first spliced frame fail
    /// verification unless it chains onto the true predecessor.
    #[test]
    fn spliced_tail_is_detected_by_the_chain() {
        let payloads: Vec<Vec<u8>> =
            vec![b"alpha".to_vec(), b"beta".to_vec(), b"gamma".to_vec()];
        let file = frame_file(LOG_MAGIC, &payloads);
        let bounds = frame_bounds(&file, LOG_MAGIC);
        // Truncate after frame 1, splice in a frame a chain-unaware
        // writer would produce (chained onto the seed, as if the file
        // were fresh). Its own CRC is internally consistent.
        let mut spliced = file[..bounds[0]].to_vec();
        let (forged, _) = encode_frame(b"forged", CHAIN_SEED);
        spliced.extend_from_slice(&forged);
        let (frames, valid) = scan_frames(&spliced, LOG_MAGIC);
        assert_eq!(frames, vec![b"alpha".to_vec()], "splice must not replay");
        assert_eq!(valid, bounds[0]);
        // A chain-aware rewrite of the same payload IS accepted — the
        // chain gates on history knowledge, not on the payload bytes.
        let true_chain = scan_frames_chained(&file[..bounds[0]], LOG_MAGIC).2;
        let mut honest = file[..bounds[0]].to_vec();
        let (ok_frame, _) = encode_frame(b"forged", true_chain);
        honest.extend_from_slice(&ok_frame);
        let (frames, _) = scan_frames(&honest, LOG_MAGIC);
        assert_eq!(frames, vec![b"alpha".to_vec(), b"forged".to_vec()]);
    }

    /// The framing invariant the whole durability design rests on:
    /// truncating the file at ANY byte offset yields exactly the frames of
    /// the last complete boundary at or before it — never a torn frame,
    /// never a lost committed one.
    #[test]
    fn prop_truncation_at_every_byte_is_boundary_equivalent() {
        forall(
            0xF4A3E5,
            25,
            |rng: &mut Rng, size| {
                let n = 1 + (6.0 * size) as usize;
                (0..n)
                    .map(|_| {
                        let len = rng.range(0, 40);
                        (0..len).map(|_| rng.below(256) as u8).collect::<Vec<u8>>()
                    })
                    .collect::<Vec<_>>()
            },
            |payloads| {
                let file = frame_file(LOG_MAGIC, payloads);
                let mut bounds = vec![HEADER_LEN];
                bounds.extend(frame_bounds(&file, LOG_MAGIC));
                if bounds.len() != payloads.len() + 1 {
                    return Err("full file must scan completely".into());
                }
                for cut in 0..=file.len() {
                    let (frames, valid) = scan_frames(&file[..cut], LOG_MAGIC);
                    let expect_k = if cut < HEADER_LEN {
                        0
                    } else {
                        bounds.iter().filter(|b| **b <= cut).count() - 1
                    };
                    if frames.len() != expect_k {
                        return Err(format!(
                            "cut {cut}: {} frames, expected {expect_k}",
                            frames.len()
                        ));
                    }
                    if frames.as_slice() != &payloads[..expect_k] {
                        return Err(format!("cut {cut}: frame bytes diverged"));
                    }
                    if cut >= HEADER_LEN && valid != bounds[expect_k] {
                        return Err(format!(
                            "cut {cut}: valid prefix {valid} != boundary {}",
                            bounds[expect_k]
                        ));
                    }
                }
                Ok(())
            },
        );
    }
}
