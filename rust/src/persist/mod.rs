//! Durable unlearning: write-ahead event log, snapshot + compaction, and
//! crash-consistent recovery.
//!
//! Edge devices reboot — satellites in eclipse, battery-cycled IoT nodes —
//! and before this subsystem a restart silently lost the lineage state,
//! the checkpoint store, and the pending/carryover unlearning queue,
//! voiding the right-to-be-forgotten guarantee the system exists to give.
//! The persist layer makes every service state transition durable:
//!
//! * [`frame`] — CRC-framed, length-prefixed binary framing. A frame is
//!   atomic by construction: a torn write degrades to "the log ends one
//!   frame earlier", never to a corrupt state.
//! * [`event`] — the transition records ([`Event`]): request submitted,
//!   samples removed, retrain executed (with RSN + warm-start receipts),
//!   checkpoint stored/evicted (payload bytes ride along in
//!   `log+spill` mode), battery settle, window carryover.
//! * [`log`] — the append-only [`EventLog`] plus the `MANIFEST.json`
//!   committed atomically on compaction.
//! * [`snapshot`] — the materialized [`StateImage`] a [`Compactor`] run
//!   writes before truncating the log prefix.
//! * [`recovery`] — replays snapshot + log tail into a freshly built
//!   service, reconstructing `UnlearningService` / `Engine` /
//!   `ModelStore` / `Lineage` / `Battery` state receipt-identically.
//!
//! ## Crash-consistency invariant
//!
//! One logical transition = one event = one frame. Recovery after a crash
//! at *any* byte offset equals recovery at the last complete frame
//! boundary, which is the post-state of event k (= the pre-state of event
//! k+1) — never a torn hybrid. `durability = off` leaves every code path
//! byte-identical to the in-memory service. Both properties are enforced
//! by the kill-point harness in `tests/durability.rs`, driven by
//! [`FailpointFs`](crate::testkit::FailpointFs).

pub mod event;
pub mod frame;
pub mod log;
pub mod recovery;
pub mod ship;
pub mod snapshot;

use std::collections::BTreeMap;
use std::io;
use std::path::{Path, PathBuf};
use std::sync::{Arc, Mutex};

pub use event::Event;
pub use log::{EventLog, Manifest};
pub use recovery::RecoveryReport;
pub use ship::{
    FileSpool, Replica, ReplicaSource, ReplicaStore, ShipReceipt, ShipTransport, Shipment,
    Shipper,
};
pub use snapshot::StateImage;

/// How much the service persists.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub enum DurabilityMode {
    /// No persistence — byte-identical to the pre-durability service.
    #[default]
    Off,
    /// Write-ahead log of every transition. Checkpoint *payloads* are not
    /// spilled: after recovery the store's accounting (sizes, stats,
    /// coverage) is exact but payload tensors are absent, so warm starts
    /// degrade to cold resets on tensor-carrying backends until fresh
    /// checkpoints accumulate. The accounting backend loses nothing.
    /// Caveat: with the **delta** codec, the identity-keyed pinned-parent
    /// byte charge cannot be re-derived without payloads, so
    /// `stored_bytes` may under-count pinned parents after recovery — use
    /// [`DurabilityMode::LogSpill`] with delta chains.
    Log,
    /// Log plus checkpoint payload spill: encoded payload bytes travel in
    /// the events/snapshot, and recovery restores them bit-exactly
    /// (delta-chain `Arc` sharing included).
    LogSpill,
}

impl DurabilityMode {
    pub fn by_name(name: &str) -> Option<DurabilityMode> {
        match name.trim().to_ascii_lowercase().as_str() {
            "off" | "none" => Some(DurabilityMode::Off),
            "log" | "wal" => Some(DurabilityMode::Log),
            "log+spill" | "log_spill" | "spill" => Some(DurabilityMode::LogSpill),
            _ => None,
        }
    }

    pub fn name(&self) -> &'static str {
        match self {
            DurabilityMode::Off => "off",
            DurabilityMode::Log => "log",
            DurabilityMode::LogSpill => "log+spill",
        }
    }

    /// Payload bytes ride along in events and snapshots.
    pub fn spills(&self) -> bool {
        matches!(self, DurabilityMode::LogSpill)
    }
}

/// When the event log issues an fsync barrier. Orthogonal to
/// [`DurabilityMode`]: the mode decides *what* is logged, the policy
/// decides when logged bytes are forced to stable storage. The default
/// keeps the flush-only behavior (and byte-for-byte file contents) of the
/// pre-fsync durability layer.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub enum FsyncPolicy {
    /// Flush-only appends; survives a process crash but not power loss.
    #[default]
    Never,
    /// One fsync per appended event — maximal durability, one barrier per
    /// transition.
    Always,
    /// Group commit: events accumulate unsynced and one fsync seals them
    /// at each commit scope — a batched window, a round ingest, a drain.
    /// The SLO-aware planner's deadline slack is exactly the fsync
    /// batching slack, so durability cost amortizes across the window.
    GroupCommit,
}

impl FsyncPolicy {
    pub fn by_name(name: &str) -> Option<FsyncPolicy> {
        match name.trim().to_ascii_lowercase().as_str() {
            "never" | "off" | "none" => Some(FsyncPolicy::Never),
            "always" | "each" | "every" | "fsync" => Some(FsyncPolicy::Always),
            "group" | "group_commit" | "group-commit" | "window" => {
                Some(FsyncPolicy::GroupCommit)
            }
            _ => None,
        }
    }

    pub fn name(&self) -> &'static str {
        match self {
            FsyncPolicy::Never => "never",
            FsyncPolicy::Always => "always",
            FsyncPolicy::GroupCommit => "group_commit",
        }
    }
}

/// The flat filesystem surface the persist layer needs. `write` must
/// replace atomically (tmp + rename on disk), because the manifest commit
/// rides on it; `append` may tear at any byte — frames absorb that.
pub trait PersistFs: Send {
    fn read(&self, name: &str) -> Option<Vec<u8>>;
    fn write(&mut self, name: &str, bytes: &[u8]) -> io::Result<()>;
    fn append(&mut self, name: &str, bytes: &[u8]) -> io::Result<()>;
    fn remove(&mut self, name: &str);

    /// Force a file's bytes to stable storage (fsync barrier). Appended
    /// bytes before a successful `sync` may be lost to power failure;
    /// bytes covered by one may not. Volatile backends (in-memory test
    /// filesystems) are their own stable storage, so the default is a
    /// no-op; a missing file syncs trivially.
    fn sync(&mut self, name: &str) -> io::Result<()> {
        let _ = name;
        Ok(())
    }
}

/// In-memory [`PersistFs`] backed by a shared map: clones see the same
/// files, which is how the kill-point tests hand a "crashed" device's disk
/// to a fresh recovery instance.
#[derive(Clone, Default)]
pub struct MemFs {
    files: Arc<Mutex<BTreeMap<String, Vec<u8>>>>,
}

impl MemFs {
    pub fn new() -> MemFs {
        MemFs::default()
    }

    /// Raw file contents (test inspection).
    pub fn file(&self, name: &str) -> Option<Vec<u8>> {
        self.files.lock().unwrap().get(name).cloned()
    }

    /// Replace a file's contents directly (test setup: truncated logs).
    pub fn put(&self, name: &str, bytes: Vec<u8>) {
        self.files.lock().unwrap().insert(name.to_string(), bytes);
    }

    /// Names and sizes of all files (compaction-ratio measurements).
    pub fn sizes(&self) -> Vec<(String, u64)> {
        self.files
            .lock()
            .unwrap()
            .iter()
            .map(|(k, v)| (k.clone(), v.len() as u64))
            .collect()
    }

    /// Deep-copy the current contents into an independent MemFs — a
    /// point-in-time disk image.
    pub fn fork(&self) -> MemFs {
        let copy = self.files.lock().unwrap().clone();
        MemFs { files: Arc::new(Mutex::new(copy)) }
    }
}

impl PersistFs for MemFs {
    fn read(&self, name: &str) -> Option<Vec<u8>> {
        self.file(name)
    }

    fn write(&mut self, name: &str, bytes: &[u8]) -> io::Result<()> {
        self.put(name, bytes.to_vec());
        Ok(())
    }

    fn append(&mut self, name: &str, bytes: &[u8]) -> io::Result<()> {
        self.files
            .lock()
            .unwrap()
            .entry(name.to_string())
            .or_default()
            .extend_from_slice(bytes);
        Ok(())
    }

    fn remove(&mut self, name: &str) {
        self.files.lock().unwrap().remove(name);
    }
}

/// Real-directory [`PersistFs`]. `write` goes through a temp file + rename
/// so the manifest commit is atomic on POSIX filesystems.
pub struct DiskFs {
    dir: PathBuf,
}

impl DiskFs {
    /// Open (creating the directory if needed).
    pub fn new(dir: impl AsRef<Path>) -> io::Result<DiskFs> {
        std::fs::create_dir_all(dir.as_ref())?;
        Ok(DiskFs { dir: dir.as_ref().to_path_buf() })
    }

    fn path(&self, name: &str) -> PathBuf {
        self.dir.join(name)
    }
}

impl PersistFs for DiskFs {
    fn read(&self, name: &str) -> Option<Vec<u8>> {
        std::fs::read(self.path(name)).ok()
    }

    fn write(&mut self, name: &str, bytes: &[u8]) -> io::Result<()> {
        let tmp = self.path(&format!("{name}.tmp"));
        std::fs::write(&tmp, bytes)?;
        std::fs::rename(&tmp, self.path(name))
    }

    fn append(&mut self, name: &str, bytes: &[u8]) -> io::Result<()> {
        use std::io::Write as _;
        let mut f = std::fs::OpenOptions::new()
            .create(true)
            .append(true)
            .open(self.path(name))?;
        f.write_all(bytes)?;
        f.flush()
    }

    fn remove(&mut self, name: &str) {
        let _ = std::fs::remove_file(self.path(name));
    }

    fn sync(&mut self, name: &str) -> io::Result<()> {
        match std::fs::File::open(self.path(name)) {
            Ok(f) => f.sync_data(),
            // A file that was never created has nothing to lose.
            Err(e) if e.kind() == io::ErrorKind::NotFound => Ok(()),
            Err(e) => Err(e),
        }
    }
}

/// Everything [`UnlearningService::attach_durability`] needs: the mode,
/// the backing filesystem, and the auto-compaction cadence.
///
/// [`UnlearningService::attach_durability`]: crate::unlearning::UnlearningService::attach_durability
pub struct Durability {
    pub mode: DurabilityMode,
    pub fs: Box<dyn PersistFs>,
    /// Auto-compact after this many events accumulate in the log tail
    /// (0 = only on explicit `compact_now`).
    pub compact_every: u64,
    /// When appended events are forced to stable storage.
    pub fsync: FsyncPolicy,
}

impl Durability {
    /// Disk-backed durability rooted at `dir`.
    pub fn disk(
        mode: DurabilityMode,
        dir: impl AsRef<Path>,
        compact_every: u64,
    ) -> io::Result<Durability> {
        Ok(Durability {
            mode,
            fs: Box::new(DiskFs::new(dir)?),
            compact_every,
            fsync: FsyncPolicy::Never,
        })
    }

    /// Memory-backed durability (tests, benches).
    pub fn mem(mode: DurabilityMode, fs: MemFs, compact_every: u64) -> Durability {
        Durability { mode, fs: Box::new(fs), compact_every, fsync: FsyncPolicy::Never }
    }

    /// Set the fsync barrier policy (builder style).
    pub fn with_fsync(mut self, fsync: FsyncPolicy) -> Durability {
        self.fsync = fsync;
        self
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn mode_names_roundtrip() {
        for m in [DurabilityMode::Off, DurabilityMode::Log, DurabilityMode::LogSpill] {
            assert_eq!(DurabilityMode::by_name(m.name()), Some(m));
        }
        assert_eq!(DurabilityMode::by_name("spill"), Some(DurabilityMode::LogSpill));
        assert_eq!(DurabilityMode::by_name("wal"), Some(DurabilityMode::Log));
        assert!(DurabilityMode::by_name("raid").is_none());
        assert!(DurabilityMode::LogSpill.spills());
        assert!(!DurabilityMode::Log.spills());
        assert_eq!(DurabilityMode::default(), DurabilityMode::Off);
    }

    #[test]
    fn fsync_policy_names_roundtrip() {
        for p in [FsyncPolicy::Never, FsyncPolicy::Always, FsyncPolicy::GroupCommit] {
            assert_eq!(FsyncPolicy::by_name(p.name()), Some(p));
        }
        assert_eq!(FsyncPolicy::by_name("window"), Some(FsyncPolicy::GroupCommit));
        assert_eq!(FsyncPolicy::by_name("fsync"), Some(FsyncPolicy::Always));
        assert!(FsyncPolicy::by_name("sometimes").is_none());
        assert_eq!(FsyncPolicy::default(), FsyncPolicy::Never);
        let d = Durability::mem(DurabilityMode::Log, MemFs::new(), 0)
            .with_fsync(FsyncPolicy::GroupCommit);
        assert_eq!(d.fsync, FsyncPolicy::GroupCommit);
    }

    #[test]
    fn memfs_clones_share_and_forks_isolate() {
        let fs = MemFs::new();
        let mut handle: Box<dyn PersistFs> = Box::new(fs.clone());
        handle.append("a.log", b"one").unwrap();
        assert_eq!(fs.file("a.log").unwrap(), b"one");
        let snap = fs.fork();
        handle.append("a.log", b"two").unwrap();
        assert_eq!(fs.file("a.log").unwrap(), b"onetwo");
        assert_eq!(snap.file("a.log").unwrap(), b"one", "fork is point-in-time");
        handle.write("a.log", b"x").unwrap();
        assert_eq!(fs.file("a.log").unwrap(), b"x");
        handle.remove("a.log");
        assert!(fs.file("a.log").is_none());
        assert!(handle.read("a.log").is_none());
    }

    #[test]
    fn diskfs_roundtrips_in_tmpdir() {
        let dir = std::env::temp_dir().join("cause_persist_diskfs_test");
        let _ = std::fs::remove_dir_all(&dir);
        let mut fs = DiskFs::new(&dir).unwrap();
        assert!(fs.read("w.log").is_none());
        fs.append("w.log", b"abc").unwrap();
        fs.append("w.log", b"def").unwrap();
        assert_eq!(fs.read("w.log").unwrap(), b"abcdef");
        fs.write("m.json", b"{}").unwrap();
        assert_eq!(fs.read("m.json").unwrap(), b"{}");
        fs.write("m.json", b"{\"a\":1}").unwrap();
        assert_eq!(fs.read("m.json").unwrap(), b"{\"a\":1}");
        fs.remove("w.log");
        assert!(fs.read("w.log").is_none());
        let _ = std::fs::remove_dir_all(&dir);
    }
}
