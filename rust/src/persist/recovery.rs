//! Crash recovery: replay snapshot + log tail into a freshly built
//! service.
//!
//! The caller builds an `UnlearningService` from the same configuration
//! the crashed instance ran (same system variant, battery profile, batch
//! planner) and calls
//! [`UnlearningService::attach_durability`](crate::unlearning::UnlearningService::attach_durability),
//! which routes here. Recovery then:
//!
//! 1. opens the manifest/log generation (repairing any torn tail),
//! 2. restores the materialized [`StateImage`] if a compaction ever ran,
//! 3. replays the log tail event by event — sequence numbers are checked,
//!    so a stale or cross-wired frame stops replay at the last consistent
//!    boundary instead of corrupting state,
//! 4. rewrites the log if any tail frames were rejected, and hands the
//!    armed [`EventLog`] back so the service resumes appending exactly
//!    where the pre-crash run left off.

use std::io;

use crate::persist::event::{Event, PayloadDedup};
use crate::persist::log::{EventLog, Opened};
use crate::persist::snapshot::StateImage;
use crate::persist::PersistFs;
use crate::unlearning::UnlearningService;

/// What a recovery pass found and did.
#[derive(Clone, Debug, Default, PartialEq, Eq)]
pub struct RecoveryReport {
    /// A compaction snapshot was restored.
    pub snapshot_loaded: bool,
    /// Events replayed from the log tail.
    pub events_replayed: u64,
    /// Torn bytes dropped (and repaired away) from the log tail.
    pub torn_bytes_dropped: u64,
    /// Complete frames rejected by sequence/decode checks (0 on any log
    /// this code wrote).
    pub frames_rejected: u64,
    /// Log size after recovery, bytes.
    pub log_bytes: u64,
}

/// Restore `svc` from the filesystem and return the armed log.
pub(crate) fn recover(
    svc: &mut UnlearningService,
    fs: Box<dyn PersistFs>,
) -> io::Result<(EventLog, RecoveryReport)> {
    let Opened { mut log, snapshot, frames, torn_bytes } = EventLog::open(fs)?;

    let mut dedup = PayloadDedup::new();
    let mut report = RecoveryReport {
        torn_bytes_dropped: torn_bytes,
        snapshot_loaded: snapshot.is_some(),
        ..RecoveryReport::default()
    };
    if let Some(bytes) = &snapshot {
        let img = StateImage::decode(bytes, &mut dedup).map_err(|e| {
            io::Error::new(io::ErrorKind::InvalidData, format!("snapshot: {e}"))
        })?;
        svc.restore_image(&img);
    }

    let base_seq = log.manifest().next_seq;
    let total = frames.len();
    let mut kept: Vec<Vec<u8>> = Vec::with_capacity(total);
    for f in frames {
        match Event::decode(&f, &mut dedup) {
            Ok((seq, ev)) if seq == base_seq + kept.len() as u64 => {
                svc.replay_event(&ev);
                kept.push(f);
            }
            _ => break,
        }
    }
    report.events_replayed = kept.len() as u64;
    report.frames_rejected = (total - kept.len()) as u64;
    if report.frames_rejected > 0 {
        log.rewrite(&kept)?;
    }
    report.log_bytes = log.log_bytes();
    Ok((log, report))
}
