//! Materialized state snapshots — what the [`Compactor`] writes so the log
//! prefix can be truncated.
//!
//! A [`StateImage`] is a complete, self-contained picture of the service:
//! clock, queue, carryover plan, battery, service/batch receipt logs,
//! engine round + per-round placements (the lineages rebuild by replaying
//! them through `LineageSet::add_round`, so prefix sums and the block
//! index come out identical), the store's exact slot layout (+ payloads in
//! spill mode), policy/partitioner counters, and the full metrics.
//!
//! Compaction is driven by
//! [`UnlearningService::compact_now`](crate::unlearning::UnlearningService::compact_now),
//! which captures the image, hands its bytes to [`EventLog::compact`]
//! (snapshot + fresh log first, atomic manifest commit second), and keeps
//! appending to the new generation.
//!
//! [`Compactor`]: crate::unlearning::UnlearningService::compact_now
//! [`EventLog::compact`]: crate::persist::EventLog::compact

use std::sync::Arc;

use crate::persist::event::{
    decode_carryover, decode_payload, encode_carryover, encode_payload, BatchReportRec,
    Dec, DecodeResult, Enc, LatencyRecord, MetaRec, PayloadDedup, PlacementRecord,
    PlanRec, ReqRecord, SvcReportRec,
};
use crate::runtime::codec::EncodedParams;

/// One resident checkpoint in the snapshot.
#[derive(Clone, Debug, PartialEq)]
pub struct SlotCkpt {
    pub id: u64,
    pub lineage: u64,
    pub round: u32,
    pub covered: u32,
    pub size_bytes: u64,
    pub payload: Option<Arc<EncodedParams>>,
}

/// The checkpoint store's exact state.
#[derive(Clone, Debug, Default, PartialEq)]
pub struct StoreImage {
    /// 0 = slots(capacity), 1 = bytes(budget).
    pub mode_tag: u8,
    pub mode_value: u64,
    pub next_id: u64,
    /// (stored, replaced, rejected, invalidated).
    pub stats: (u64, u64, u64, u64),
    pub slots: Vec<Option<SlotCkpt>>,
    pub policy_state: Vec<u64>,
}

/// The battery's full state (capacity included, so a recovered device in
/// eclipse does not wake up fully charged).
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct BatteryImage {
    pub capacity_j: f64,
    pub charge_j: f64,
    pub harvest_watts: f64,
    pub brownouts: u64,
}

/// Full run metrics.
#[derive(Clone, Debug, Default, PartialEq)]
pub struct MetricsImage {
    pub rsn_by_round: Vec<u64>,
    pub requests_by_round: Vec<u64>,
    pub warm_retrains: u64,
    pub scratch_retrains: u64,
    pub lineages_retrained: u64,
    pub energy_joules: f64,
    pub prunes: u64,
    pub ckpts_stored: u64,
    pub ckpts_replaced: u64,
    pub ckpts_rejected: u64,
    pub ckpts_invalidated: u64,
    pub batches: u64,
    pub batched_requests: u64,
    pub retrains_coalesced: u64,
    pub latency: Vec<LatencyRecord>,
    pub accuracy_by_round: Vec<Option<f64>>,
    /// Receipts dropped past the retention cap and SLO misses counted at
    /// record time (see `RunMetrics`), plus the latency histogram's raw
    /// parts — its u128 sum rides as two u64 halves.
    pub latency_dropped: u64,
    pub latency_slo_miss: u64,
    pub hist_counts: Vec<u64>,
    pub hist_count: u64,
    pub hist_sum_hi: u64,
    pub hist_sum_lo: u64,
    pub hist_max: u64,
}

/// Everything recovery needs to rebuild the service without the log
/// prefix.
#[derive(Clone, Debug, Default, PartialEq)]
pub struct StateImage {
    pub now_tick: u64,
    pub head_deferral_logged: bool,
    pub queue: Vec<ReqRecord>,
    pub carryover: Option<(PlanRec, Vec<MetaRec>)>,
    pub battery: Option<BatteryImage>,
    pub svc_log: Vec<SvcReportRec>,
    pub batch_log: Vec<BatchReportRec>,
    pub round: u32,
    /// Per training round: the placements it added (current sample counts,
    /// so unlearned data stays unlearned after the rebuild).
    pub rounds: Vec<(u32, Vec<PlacementRecord>)>,
    pub partitioner_state: Vec<u64>,
    pub store: StoreImage,
    pub metrics: MetricsImage,
}

impl StateImage {
    /// Serialize; `spill` controls whether checkpoint payloads ride along.
    pub fn encode(&self, spill: bool) -> Vec<u8> {
        let mut e = Enc::new();
        e.u64(self.now_tick);
        e.bool(self.head_deferral_logged);

        e.u64(self.queue.len() as u64);
        for r in &self.queue {
            e.u32(r.user);
            e.u32(r.round);
            e.u64(r.arrival_tick);
            e.u64(r.parts.len() as u64);
            for (b, n) in &r.parts {
                e.u64(*b);
                e.u64(*n);
            }
        }

        encode_carryover(&mut e, &self.carryover);

        match &self.battery {
            None => e.bool(false),
            Some(b) => {
                e.bool(true);
                e.f64(b.capacity_j);
                e.f64(b.charge_j);
                e.f64(b.harvest_watts);
                e.u64(b.brownouts);
            }
        }

        e.u64(self.svc_log.len() as u64);
        for r in &self.svc_log {
            e.u32(r.user);
            e.u32(r.round);
            e.u64(r.rsn);
            e.u64(r.lineages_retrained);
            e.f64(r.est_seconds);
            e.f64(r.est_joules);
            e.bool(r.deferred);
        }
        e.u64(self.batch_log.len() as u64);
        for r in &self.batch_log {
            e.u64(r.requests);
            e.u64(r.rsn);
            e.u64(r.lineages_retrained);
            e.u64(r.retrains_coalesced);
            e.u64(r.oldest_queued_ticks);
            e.f64(r.est_seconds);
            e.f64(r.est_joules);
            e.bool(r.deferred);
        }

        e.u32(self.round);
        e.u64(self.rounds.len() as u64);
        for (round, placements) in &self.rounds {
            e.u32(*round);
            e.u64(placements.len() as u64);
            for p in placements {
                e.u64(p.block);
                e.u32(p.user);
                e.u64(p.shard);
                e.u64(p.samples);
            }
        }
        e.words(&self.partitioner_state);

        e.u8(self.store.mode_tag);
        e.u64(self.store.mode_value);
        e.u64(self.store.next_id);
        e.u64(self.store.stats.0);
        e.u64(self.store.stats.1);
        e.u64(self.store.stats.2);
        e.u64(self.store.stats.3);
        e.u64(self.store.slots.len() as u64);
        for s in &self.store.slots {
            match s {
                None => e.bool(false),
                Some(c) => {
                    e.bool(true);
                    e.u64(c.id);
                    e.u64(c.lineage);
                    e.u32(c.round);
                    e.u32(c.covered);
                    e.u64(c.size_bytes);
                    match &c.payload {
                        Some(p) if spill => {
                            e.bool(true);
                            encode_payload(&mut e, p);
                        }
                        _ => e.bool(false),
                    }
                }
            }
        }
        e.words(&self.store.policy_state);

        let m = &self.metrics;
        e.words(&m.rsn_by_round);
        e.words(&m.requests_by_round);
        e.u64(m.warm_retrains);
        e.u64(m.scratch_retrains);
        e.u64(m.lineages_retrained);
        e.f64(m.energy_joules);
        e.u64(m.prunes);
        e.u64(m.ckpts_stored);
        e.u64(m.ckpts_replaced);
        e.u64(m.ckpts_rejected);
        e.u64(m.ckpts_invalidated);
        e.u64(m.batches);
        e.u64(m.batched_requests);
        e.u64(m.retrains_coalesced);
        e.u64(m.latency.len() as u64);
        for l in &m.latency {
            e.u32(l.user);
            e.u32(l.round);
            e.u64(l.queued_ticks);
            e.bool(l.slo_met);
        }
        e.u64(m.accuracy_by_round.len() as u64);
        for a in &m.accuracy_by_round {
            match a {
                None => e.bool(false),
                Some(v) => {
                    e.bool(true);
                    e.f64(*v);
                }
            }
        }
        e.u64(m.latency_dropped);
        e.u64(m.latency_slo_miss);
        e.words(&m.hist_counts);
        e.u64(m.hist_count);
        e.u64(m.hist_sum_hi);
        e.u64(m.hist_sum_lo);
        e.u64(m.hist_max);
        e.buf
    }

    /// Deserialize a snapshot payload.
    pub fn decode(bytes: &[u8], dedup: &mut PayloadDedup) -> DecodeResult<StateImage> {
        let mut d = Dec::new(bytes);
        let now_tick = d.u64()?;
        let head_deferral_logged = d.bool()?;

        let nq = d.count()?;
        let mut queue = Vec::with_capacity(nq.min(1 << 12));
        for _ in 0..nq {
            let user = d.u32()?;
            let round = d.u32()?;
            let arrival_tick = d.u64()?;
            let np = d.count()?;
            let mut parts = Vec::with_capacity(np.min(1 << 12));
            for _ in 0..np {
                parts.push((d.u64()?, d.u64()?));
            }
            queue.push(ReqRecord { user, round, arrival_tick, parts });
        }

        let carryover = decode_carryover(&mut d)?;

        let battery = if d.bool()? {
            Some(BatteryImage {
                capacity_j: d.f64()?,
                charge_j: d.f64()?,
                harvest_watts: d.f64()?,
                brownouts: d.u64()?,
            })
        } else {
            None
        };

        let ns = d.count()?;
        let mut svc_log = Vec::with_capacity(ns.min(1 << 14));
        for _ in 0..ns {
            svc_log.push(SvcReportRec {
                user: d.u32()?,
                round: d.u32()?,
                rsn: d.u64()?,
                lineages_retrained: d.u64()?,
                est_seconds: d.f64()?,
                est_joules: d.f64()?,
                deferred: d.bool()?,
            });
        }
        let nb = d.count()?;
        let mut batch_log = Vec::with_capacity(nb.min(1 << 14));
        for _ in 0..nb {
            batch_log.push(BatchReportRec {
                requests: d.u64()?,
                rsn: d.u64()?,
                lineages_retrained: d.u64()?,
                retrains_coalesced: d.u64()?,
                oldest_queued_ticks: d.u64()?,
                est_seconds: d.f64()?,
                est_joules: d.f64()?,
                deferred: d.bool()?,
            });
        }

        let round = d.u32()?;
        let nr = d.count()?;
        let mut rounds = Vec::with_capacity(nr.min(1 << 12));
        for _ in 0..nr {
            let r = d.u32()?;
            let np = d.count()?;
            let mut placements = Vec::with_capacity(np.min(1 << 12));
            for _ in 0..np {
                placements.push(PlacementRecord {
                    block: d.u64()?,
                    user: d.u32()?,
                    shard: d.u64()?,
                    samples: d.u64()?,
                });
            }
            rounds.push((r, placements));
        }
        let partitioner_state = d.words()?;

        let mode_tag = d.u8()?;
        let mode_value = d.u64()?;
        let next_id = d.u64()?;
        let stats = (d.u64()?, d.u64()?, d.u64()?, d.u64()?);
        let nslots = d.count()?;
        let mut slots = Vec::with_capacity(nslots.min(1 << 14));
        for _ in 0..nslots {
            if d.bool()? {
                let id = d.u64()?;
                let lineage = d.u64()?;
                let round = d.u32()?;
                let covered = d.u32()?;
                let size_bytes = d.u64()?;
                let payload =
                    if d.bool()? { Some(decode_payload(&mut d, dedup)?) } else { None };
                slots.push(Some(SlotCkpt { id, lineage, round, covered, size_bytes, payload }));
            } else {
                slots.push(None);
            }
        }
        let policy_state = d.words()?;
        let store = StoreImage { mode_tag, mode_value, next_id, stats, slots, policy_state };

        let rsn_by_round = d.words()?;
        let requests_by_round = d.words()?;
        let warm_retrains = d.u64()?;
        let scratch_retrains = d.u64()?;
        let lineages_retrained = d.u64()?;
        let energy_joules = d.f64()?;
        let prunes = d.u64()?;
        let ckpts_stored = d.u64()?;
        let ckpts_replaced = d.u64()?;
        let ckpts_rejected = d.u64()?;
        let ckpts_invalidated = d.u64()?;
        let batches = d.u64()?;
        let batched_requests = d.u64()?;
        let retrains_coalesced = d.u64()?;
        let nl = d.count()?;
        let mut latency = Vec::with_capacity(nl.min(1 << 14));
        for _ in 0..nl {
            latency.push(LatencyRecord {
                user: d.u32()?,
                round: d.u32()?,
                queued_ticks: d.u64()?,
                slo_met: d.bool()?,
            });
        }
        let na = d.count()?;
        let mut accuracy_by_round = Vec::with_capacity(na.min(1 << 12));
        for _ in 0..na {
            accuracy_by_round.push(if d.bool()? { Some(d.f64()?) } else { None });
        }
        let latency_dropped = d.u64()?;
        let latency_slo_miss = d.u64()?;
        let hist_counts = d.words()?;
        let hist_count = d.u64()?;
        let hist_sum_hi = d.u64()?;
        let hist_sum_lo = d.u64()?;
        let hist_max = d.u64()?;
        d.finished()?;

        Ok(StateImage {
            now_tick,
            head_deferral_logged,
            queue,
            carryover,
            battery,
            svc_log,
            batch_log,
            round,
            rounds,
            partitioner_state,
            store,
            metrics: MetricsImage {
                rsn_by_round,
                requests_by_round,
                warm_retrains,
                scratch_retrains,
                lineages_retrained,
                energy_joules,
                prunes,
                ckpts_stored,
                ckpts_replaced,
                ckpts_rejected,
                ckpts_invalidated,
                batches,
                batched_requests,
                retrains_coalesced,
                latency,
                accuracy_by_round,
                latency_dropped,
                latency_slo_miss,
                hist_counts,
                hist_count,
                hist_sum_hi,
                hist_sum_lo,
                hist_max,
            },
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::runtime::codec::{CodecMode, TensorCodec};
    use crate::runtime::HostTensor;

    fn sample_image() -> StateImage {
        StateImage {
            now_tick: 42,
            head_deferral_logged: true,
            queue: vec![ReqRecord {
                user: 7,
                round: 3,
                arrival_tick: 40,
                parts: vec![(11, 25), (12, 4)],
            }],
            carryover: Some((
                PlanRec { lineages: vec![(2, vec![0, 3], 2)], requests: 2 },
                vec![MetaRec { user: 9, round: 2, arrival_tick: 39 }],
            )),
            battery: Some(BatteryImage {
                capacity_j: 72_000.0,
                charge_j: 1234.5,
                harvest_watts: 4.0,
                brownouts: 3,
            }),
            svc_log: vec![SvcReportRec {
                user: 1,
                round: 1,
                rsn: 100,
                lineages_retrained: 1,
                est_seconds: 2.5,
                est_joules: 37.5,
                deferred: false,
            }],
            batch_log: vec![BatchReportRec {
                requests: 4,
                rsn: 900,
                lineages_retrained: 2,
                retrains_coalesced: 3,
                oldest_queued_ticks: 5,
                est_seconds: 20.0,
                est_joules: 300.0,
                deferred: false,
            }],
            round: 4,
            rounds: vec![
                (1, vec![PlacementRecord { block: 0, user: 1, shard: 0, samples: 90 }]),
                (
                    2,
                    vec![
                        PlacementRecord { block: 1, user: 2, shard: 1, samples: 50 },
                        PlacementRecord { block: 2, user: 1, shard: 0, samples: 0 },
                    ],
                ),
            ],
            partitioner_state: vec![1, 2, 3],
            store: StoreImage {
                mode_tag: 1,
                mode_value: 4096,
                next_id: 9,
                stats: (8, 2, 1, 3),
                slots: vec![
                    Some(SlotCkpt {
                        id: 5,
                        lineage: 0,
                        round: 3,
                        covered: 3,
                        size_bytes: 700,
                        payload: None,
                    }),
                    None,
                    Some(SlotCkpt {
                        id: 8,
                        lineage: 1,
                        round: 4,
                        covered: 4,
                        size_bytes: 650,
                        payload: None,
                    }),
                ],
                policy_state: vec![4, 5, 6, 7, 8],
            },
            metrics: MetricsImage {
                rsn_by_round: vec![0, 100, 900, 0],
                requests_by_round: vec![0, 1, 4, 0],
                warm_retrains: 3,
                scratch_retrains: 1,
                lineages_retrained: 3,
                energy_joules: 412.75,
                prunes: 16,
                ckpts_stored: 8,
                ckpts_replaced: 2,
                ckpts_rejected: 1,
                ckpts_invalidated: 3,
                batches: 2,
                batched_requests: 5,
                retrains_coalesced: 3,
                latency: vec![LatencyRecord { user: 1, round: 1, queued_ticks: 0, slo_met: true }],
                accuracy_by_round: vec![None, Some(0.71), None, None],
                latency_dropped: 2,
                latency_slo_miss: 1,
                hist_counts: vec![1, 0, 2],
                hist_count: 3,
                hist_sum_hi: 0,
                hist_sum_lo: 9,
                hist_max: 4,
            },
        }
    }

    #[test]
    fn image_roundtrips_without_spill() {
        let img = sample_image();
        let bytes = img.encode(false);
        let mut dedup = PayloadDedup::new();
        let got = StateImage::decode(&bytes, &mut dedup).expect("decode");
        assert_eq!(got, img);
    }

    #[test]
    fn image_roundtrips_with_spilled_payloads() {
        let codec = TensorCodec::new(CodecMode::Sparse);
        let tensors = vec![HostTensor::from_fn(&[40], |i| if i % 3 == 0 { i as f32 } else { 0.0 })];
        let payload = Arc::new(codec.encode(&tensors, None));
        let mut img = sample_image();
        img.store.slots[0].as_mut().unwrap().payload = Some(payload.clone());
        img.store.slots[0].as_mut().unwrap().size_bytes = payload.size_bytes();

        let bytes = img.encode(true);
        let mut dedup = PayloadDedup::new();
        let got = StateImage::decode(&bytes, &mut dedup).expect("decode");
        let got_payload =
            got.store.slots[0].as_ref().unwrap().payload.as_ref().expect("spilled");
        assert_eq!(got_payload.decode(), tensors, "payload bit-exact");
        assert_eq!(got_payload.uid(), payload.uid());
        assert_eq!(got, img);

        // Without spill, payloads are dropped but sizes survive.
        let lean = StateImage::decode(&img.encode(false), &mut PayloadDedup::new()).unwrap();
        assert!(lean.store.slots[0].as_ref().unwrap().payload.is_none());
        assert_eq!(
            lean.store.slots[0].as_ref().unwrap().size_bytes,
            payload.size_bytes()
        );
    }

    #[test]
    fn truncated_image_fails_loudly() {
        let bytes = sample_image().encode(false);
        for cut in [0, 1, 8, bytes.len() / 2, bytes.len() - 1] {
            assert!(
                StateImage::decode(&bytes[..cut], &mut PayloadDedup::new()).is_err(),
                "cut {cut} must not decode"
            );
        }
        let mut extra = bytes.clone();
        extra.push(0);
        assert!(StateImage::decode(&extra, &mut PayloadDedup::new()).is_err());
    }
}
