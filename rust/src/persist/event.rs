//! Durable event records: every state transition of the unlearning
//! service, in a self-contained binary form.
//!
//! One logical transition = one [`Event`] = one log frame, so recovery is
//! always either pre-event or post-event state — never a torn mix. Events
//! carry the transition's *inputs* where replay is deterministic (queue
//! pops re-remove their own samples through the same proportional-split
//! code) and *effects* where it is not re-derivable without the trainer
//! (store admissions with their exact victim sets, scalar metric
//! post-values, battery post-charge, receipt pushes, policy/partitioner
//! counters). Checkpoint payload bytes ride along only in
//! `durability = log+spill` mode, keyed by the payload's
//! [`EncodedParams::uid`] so `Arc` sharing across a delta chain is
//! re-established on replay.
//!
//! Scalar accumulators (energy joules, battery charge) are recorded as
//! absolute post-transition values, not deltas — floating-point deltas do
//! not re-add bit-exactly, absolute values do.

use std::collections::HashMap;
use std::sync::Arc;

use crate::memory::{Checkpoint, CheckpointId, StoreEvent};
use crate::runtime::codec::{EncodedParams, EncodedTensor, TensorBlock};

/// Decode result.
pub type DecodeResult<T> = Result<T, String>;

/// Little-endian byte writer for event payloads.
#[derive(Default)]
pub struct Enc {
    pub buf: Vec<u8>,
}

impl Enc {
    pub fn new() -> Enc {
        Enc { buf: Vec::new() }
    }

    pub fn u8(&mut self, v: u8) {
        self.buf.push(v);
    }

    pub fn bool(&mut self, v: bool) {
        self.buf.push(v as u8);
    }

    pub fn u32(&mut self, v: u32) {
        self.buf.extend_from_slice(&v.to_le_bytes());
    }

    pub fn u64(&mut self, v: u64) {
        self.buf.extend_from_slice(&v.to_le_bytes());
    }

    pub fn f64(&mut self, v: f64) {
        self.u64(v.to_bits());
    }

    pub fn f32(&mut self, v: f32) {
        self.buf.extend_from_slice(&v.to_bits().to_le_bytes());
    }

    pub fn words(&mut self, w: &[u64]) {
        self.u64(w.len() as u64);
        for v in w {
            self.u64(*v);
        }
    }
}

/// Little-endian byte reader mirroring [`Enc`].
pub struct Dec<'a> {
    buf: &'a [u8],
    pos: usize,
}

impl<'a> Dec<'a> {
    pub fn new(buf: &'a [u8]) -> Dec<'a> {
        Dec { buf, pos: 0 }
    }

    fn take(&mut self, n: usize) -> DecodeResult<&'a [u8]> {
        let end = self.pos.checked_add(n).ok_or("length overflow")?;
        let s = self.buf.get(self.pos..end).ok_or("truncated event payload")?;
        self.pos = end;
        Ok(s)
    }

    pub fn u8(&mut self) -> DecodeResult<u8> {
        Ok(self.take(1)?[0])
    }

    pub fn bool(&mut self) -> DecodeResult<bool> {
        match self.u8()? {
            0 => Ok(false),
            1 => Ok(true),
            other => Err(format!("invalid bool byte {other}")),
        }
    }

    pub fn u32(&mut self) -> DecodeResult<u32> {
        let b = self.take(4)?;
        Ok(u32::from_le_bytes([b[0], b[1], b[2], b[3]]))
    }

    pub fn u64(&mut self) -> DecodeResult<u64> {
        let b = self.take(8)?;
        Ok(u64::from_le_bytes([b[0], b[1], b[2], b[3], b[4], b[5], b[6], b[7]]))
    }

    pub fn f64(&mut self) -> DecodeResult<f64> {
        Ok(f64::from_bits(self.u64()?))
    }

    pub fn f32(&mut self) -> DecodeResult<f32> {
        let b = self.take(4)?;
        Ok(f32::from_bits(u32::from_le_bytes([b[0], b[1], b[2], b[3]])))
    }

    /// Bounded element count: corrupt lengths must not allocate the moon.
    pub fn count(&mut self) -> DecodeResult<usize> {
        let n = self.u64()?;
        if n > (1 << 32) {
            return Err(format!("implausible element count {n}"));
        }
        Ok(n as usize)
    }

    pub fn words(&mut self) -> DecodeResult<Vec<u64>> {
        let n = self.count()?;
        let mut out = Vec::with_capacity(n.min(1 << 16));
        for _ in 0..n {
            out.push(self.u64()?);
        }
        Ok(out)
    }

    pub fn finished(&self) -> DecodeResult<()> {
        if self.pos == self.buf.len() {
            Ok(())
        } else {
            Err(format!("{} trailing bytes", self.buf.len() - self.pos))
        }
    }
}

// ---------------------------------------------------------------------------
// Leaf records
// ---------------------------------------------------------------------------

/// One queued unlearning request.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct ReqRecord {
    pub user: u32,
    pub round: u32,
    pub arrival_tick: u64,
    /// (block id, samples to remove).
    pub parts: Vec<(u64, u64)>,
}

impl ReqRecord {
    fn encode(&self, e: &mut Enc) {
        e.u32(self.user);
        e.u32(self.round);
        e.u64(self.arrival_tick);
        e.u64(self.parts.len() as u64);
        for (b, n) in &self.parts {
            e.u64(*b);
            e.u64(*n);
        }
    }

    fn decode(d: &mut Dec) -> DecodeResult<ReqRecord> {
        let user = d.u32()?;
        let round = d.u32()?;
        let arrival_tick = d.u64()?;
        let n = d.count()?;
        let mut parts = Vec::with_capacity(n.min(1 << 16));
        for _ in 0..n {
            parts.push((d.u64()?, d.u64()?));
        }
        Ok(ReqRecord { user, round, arrival_tick, parts })
    }
}

/// Battery state after a transition (absolute, bit-exact).
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct BatteryPost {
    pub charge_j: f64,
    pub brownouts: u64,
}

fn encode_battery(e: &mut Enc, b: &Option<BatteryPost>) {
    match b {
        None => e.bool(false),
        Some(p) => {
            e.bool(true);
            e.f64(p.charge_j);
            e.u64(p.brownouts);
        }
    }
}

fn decode_battery(d: &mut Dec) -> DecodeResult<Option<BatteryPost>> {
    if d.bool()? {
        Ok(Some(BatteryPost { charge_j: d.f64()?, brownouts: d.u64()? }))
    } else {
        Ok(None)
    }
}

/// One block placement of a training round (post-partitioner).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct PlacementRecord {
    pub block: u64,
    pub user: u32,
    pub shard: u64,
    pub samples: u64,
}

/// Store-event shape of a recorded admission.
#[derive(Clone, Debug, PartialEq, Eq)]
pub enum StoreEvRec {
    Stored { slot: u64 },
    Replaced { slot: u64, evicted: u64 },
    Evicted { slot: u64, victims: Vec<u64> },
    Rejected,
}

impl StoreEvRec {
    pub fn from_event(e: &StoreEvent) -> StoreEvRec {
        match e {
            StoreEvent::Stored { slot } => StoreEvRec::Stored { slot: *slot as u64 },
            StoreEvent::Replaced { slot, evicted } => {
                StoreEvRec::Replaced { slot: *slot as u64, evicted: evicted.0 }
            }
            StoreEvent::Evicted { slot, victims } => StoreEvRec::Evicted {
                slot: *slot as u64,
                victims: victims.iter().map(|v| v.0).collect(),
            },
            StoreEvent::Rejected => StoreEvRec::Rejected,
        }
    }

    pub fn to_event(&self) -> StoreEvent {
        match self {
            StoreEvRec::Stored { slot } => StoreEvent::Stored { slot: *slot as usize },
            StoreEvRec::Replaced { slot, evicted } => StoreEvent::Replaced {
                slot: *slot as usize,
                evicted: CheckpointId(*evicted),
            },
            StoreEvRec::Evicted { slot, victims } => StoreEvent::Evicted {
                slot: *slot as usize,
                victims: victims.iter().map(|v| CheckpointId(*v)).collect(),
            },
            StoreEvRec::Rejected => StoreEvent::Rejected,
        }
    }

    fn encode(&self, e: &mut Enc) {
        match self {
            StoreEvRec::Stored { slot } => {
                e.u8(0);
                e.u64(*slot);
            }
            StoreEvRec::Replaced { slot, evicted } => {
                e.u8(1);
                e.u64(*slot);
                e.u64(*evicted);
            }
            StoreEvRec::Evicted { slot, victims } => {
                e.u8(2);
                e.u64(*slot);
                e.words(victims);
            }
            StoreEvRec::Rejected => e.u8(3),
        }
    }

    fn decode(d: &mut Dec) -> DecodeResult<StoreEvRec> {
        Ok(match d.u8()? {
            0 => StoreEvRec::Stored { slot: d.u64()? },
            1 => StoreEvRec::Replaced { slot: d.u64()?, evicted: d.u64()? },
            2 => StoreEvRec::Evicted { slot: d.u64()?, victims: d.words()? },
            3 => StoreEvRec::Rejected,
            t => return Err(format!("unknown store event tag {t}")),
        })
    }
}

/// One store mutation as the engine performed it.
#[derive(Clone, Debug, PartialEq)]
pub enum StoreOpRec {
    /// A `store()` call: the checkpoint (payload attached in spill mode)
    /// and the event the live store returned.
    Store {
        id: u64,
        lineage: u64,
        round: u32,
        covered: u32,
        size_bytes: u64,
        payload: Option<Arc<EncodedParams>>,
        event: StoreEvRec,
    },
    /// The engine's probe-and-skip rejection (id allocated, nothing
    /// materialized).
    SkipReject { id: u64 },
    /// Checkpoint versions deleted by Alg. 3 line 11, by id.
    Invalidate { ids: Vec<u64> },
}

impl StoreOpRec {
    /// The checkpoint to replay for a `Store` op (`None` for the others).
    pub fn to_checkpoint(&self) -> Option<Checkpoint> {
        match self {
            StoreOpRec::Store { id, lineage, round, covered, size_bytes, payload, .. } => {
                Some(Checkpoint {
                    id: CheckpointId(*id),
                    lineage: *lineage as usize,
                    round: *round,
                    covered_segments: *covered,
                    size_bytes: *size_bytes,
                    params: payload.clone(),
                })
            }
            _ => None,
        }
    }

    fn encode(&self, e: &mut Enc, spill: bool) {
        match self {
            StoreOpRec::Store { id, lineage, round, covered, size_bytes, payload, event } => {
                e.u8(0);
                e.u64(*id);
                e.u64(*lineage);
                e.u32(*round);
                e.u32(*covered);
                e.u64(*size_bytes);
                match payload {
                    Some(p) if spill => {
                        e.bool(true);
                        encode_payload(e, p);
                    }
                    _ => e.bool(false),
                }
                event.encode(e);
            }
            StoreOpRec::SkipReject { id } => {
                e.u8(1);
                e.u64(*id);
            }
            StoreOpRec::Invalidate { ids } => {
                e.u8(2);
                e.words(ids);
            }
        }
    }

    fn decode(d: &mut Dec, dedup: &mut PayloadDedup) -> DecodeResult<StoreOpRec> {
        Ok(match d.u8()? {
            0 => {
                let id = d.u64()?;
                let lineage = d.u64()?;
                let round = d.u32()?;
                let covered = d.u32()?;
                let size_bytes = d.u64()?;
                let payload =
                    if d.bool()? { Some(decode_payload(d, dedup)?) } else { None };
                let event = StoreEvRec::decode(d)?;
                StoreOpRec::Store { id, lineage, round, covered, size_bytes, payload, event }
            }
            1 => StoreOpRec::SkipReject { id: d.u64()? },
            2 => StoreOpRec::Invalidate { ids: d.words()? },
            t => return Err(format!("unknown store op tag {t}")),
        })
    }
}

fn encode_ops(e: &mut Enc, ops: &[StoreOpRec], spill: bool) {
    e.u64(ops.len() as u64);
    for op in ops {
        op.encode(e, spill);
    }
}

fn decode_ops(d: &mut Dec, dedup: &mut PayloadDedup) -> DecodeResult<Vec<StoreOpRec>> {
    let n = d.count()?;
    let mut out = Vec::with_capacity(n.min(1 << 12));
    for _ in 0..n {
        out.push(StoreOpRec::decode(d, dedup)?);
    }
    Ok(out)
}

// ---------------------------------------------------------------------------
// Payload spill (EncodedParams ↔ bytes)
// ---------------------------------------------------------------------------

/// uid → reconstructed payload: chains spilled by several events share
/// their parents again after replay (the identity-keyed byte accounting in
/// the store depends on it).
pub type PayloadDedup = HashMap<u64, Arc<EncodedParams>>;

fn encode_tensor(e: &mut Enc, t: &EncodedTensor) {
    e.u64(t.dims.len() as u64);
    for d in &t.dims {
        e.u64(*d as u64);
    }
    let (tag, mask, values): (u8, &[u64], &[f32]) = match &t.block {
        TensorBlock::Dense { data } => (0, &[], data),
        TensorBlock::Sparse { mask, values } => (1, mask, values),
        TensorBlock::Delta { mask, values } => (2, mask, values),
    };
    e.u8(tag);
    if tag != 0 {
        e.words(mask);
    }
    e.u64(values.len() as u64);
    for v in values {
        e.f32(*v);
    }
}

fn decode_tensor(d: &mut Dec) -> DecodeResult<EncodedTensor> {
    let nd = d.count()?;
    let mut dims = Vec::with_capacity(nd.min(16));
    for _ in 0..nd {
        dims.push(d.u64()? as usize);
    }
    let tag = d.u8()?;
    let mask = if tag != 0 { d.words()? } else { Vec::new() };
    let nv = d.count()?;
    let mut values = Vec::with_capacity(nv.min(1 << 20));
    for _ in 0..nv {
        values.push(d.f32()?);
    }
    let block = match tag {
        0 => TensorBlock::Dense { data: values },
        1 => TensorBlock::Sparse { mask, values },
        2 => TensorBlock::Delta { mask, values },
        t => return Err(format!("unknown tensor block tag {t}")),
    };
    Ok(EncodedTensor { dims, block })
}

/// Serialize a payload with its full pinned parent chain, child first.
pub(crate) fn encode_payload(e: &mut Enc, p: &Arc<EncodedParams>) {
    let chain = crate::runtime::codec::payload_chain(p);
    e.u64(chain.len() as u64);
    for level in &chain {
        e.u64(level.uid());
        e.u64(level.tensors.len() as u64);
        for t in &level.tensors {
            encode_tensor(e, t);
        }
    }
}

/// Rebuild a payload chain, reusing payloads the dedup map already holds.
pub(crate) fn decode_payload(d: &mut Dec, dedup: &mut PayloadDedup) -> DecodeResult<Arc<EncodedParams>> {
    let levels = d.count()?;
    if levels == 0 || levels > 64 {
        return Err(format!("implausible payload chain length {levels}"));
    }
    let mut decoded: Vec<(u64, Vec<EncodedTensor>)> = Vec::with_capacity(levels);
    for _ in 0..levels {
        let uid = d.u64()?;
        let nt = d.count()?;
        let mut tensors = Vec::with_capacity(nt.min(256));
        for _ in 0..nt {
            tensors.push(decode_tensor(d)?);
        }
        decoded.push((uid, tensors));
    }
    // Link root-first so each child points at its (possibly shared) parent.
    let mut cur: Option<Arc<EncodedParams>> = None;
    for (uid, tensors) in decoded.into_iter().rev() {
        if let Some(hit) = dedup.get(&uid) {
            cur = Some(hit.clone());
            continue;
        }
        let p = Arc::new(EncodedParams::from_parts(tensors, cur.clone(), uid));
        dedup.insert(uid, p.clone());
        cur = Some(p);
    }
    cur.ok_or_else(|| "empty payload chain".to_string())
}

// ---------------------------------------------------------------------------
// Metric / receipt records
// ---------------------------------------------------------------------------

/// Absolute post-transition values of every scalar metric a transition can
/// touch, plus the by-round slot count and last-slot values.
#[derive(Clone, Copy, Debug, Default, PartialEq)]
pub struct MetricsPost {
    pub warm_retrains: u64,
    pub scratch_retrains: u64,
    pub lineages_retrained: u64,
    pub prunes: u64,
    pub energy_joules: f64,
    pub ckpts_stored: u64,
    pub ckpts_replaced: u64,
    pub ckpts_rejected: u64,
    pub ckpts_invalidated: u64,
    pub batches: u64,
    pub batched_requests: u64,
    pub retrains_coalesced: u64,
    /// Length of `rsn_by_round` / `requests_by_round` after the
    /// transition (a round opens a slot; a pre-round request opens slot 0).
    pub round_slots: u64,
    /// Last-slot values after the transition (0 when no slot exists).
    pub rsn_last: u64,
    pub requests_last: u64,
}

impl MetricsPost {
    fn encode(&self, e: &mut Enc) {
        e.u64(self.warm_retrains);
        e.u64(self.scratch_retrains);
        e.u64(self.lineages_retrained);
        e.u64(self.prunes);
        e.f64(self.energy_joules);
        e.u64(self.ckpts_stored);
        e.u64(self.ckpts_replaced);
        e.u64(self.ckpts_rejected);
        e.u64(self.ckpts_invalidated);
        e.u64(self.batches);
        e.u64(self.batched_requests);
        e.u64(self.retrains_coalesced);
        e.u64(self.round_slots);
        e.u64(self.rsn_last);
        e.u64(self.requests_last);
    }

    fn decode(d: &mut Dec) -> DecodeResult<MetricsPost> {
        Ok(MetricsPost {
            warm_retrains: d.u64()?,
            scratch_retrains: d.u64()?,
            lineages_retrained: d.u64()?,
            prunes: d.u64()?,
            energy_joules: d.f64()?,
            ckpts_stored: d.u64()?,
            ckpts_replaced: d.u64()?,
            ckpts_rejected: d.u64()?,
            ckpts_invalidated: d.u64()?,
            batches: d.u64()?,
            batched_requests: d.u64()?,
            retrains_coalesced: d.u64()?,
            round_slots: d.u64()?,
            rsn_last: d.u64()?,
            requests_last: d.u64()?,
        })
    }
}

/// One latency receipt pushed by the transition.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct LatencyRecord {
    pub user: u32,
    pub round: u32,
    pub queued_ticks: u64,
    pub slo_met: bool,
}

impl LatencyRecord {
    fn encode(&self, e: &mut Enc) {
        e.u32(self.user);
        e.u32(self.round);
        e.u64(self.queued_ticks);
        e.bool(self.slo_met);
    }

    fn decode(d: &mut Dec) -> DecodeResult<LatencyRecord> {
        Ok(LatencyRecord {
            user: d.u32()?,
            round: d.u32()?,
            queued_ticks: d.u64()?,
            slo_met: d.bool()?,
        })
    }
}

/// Mirror of [`ServiceReport`](crate::unlearning::ServiceReport).
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct SvcReportRec {
    pub user: u32,
    pub round: u32,
    pub rsn: u64,
    pub lineages_retrained: u64,
    pub est_seconds: f64,
    pub est_joules: f64,
    pub deferred: bool,
}

impl SvcReportRec {
    fn encode(&self, e: &mut Enc) {
        e.u32(self.user);
        e.u32(self.round);
        e.u64(self.rsn);
        e.u64(self.lineages_retrained);
        e.f64(self.est_seconds);
        e.f64(self.est_joules);
        e.bool(self.deferred);
    }

    fn decode(d: &mut Dec) -> DecodeResult<SvcReportRec> {
        Ok(SvcReportRec {
            user: d.u32()?,
            round: d.u32()?,
            rsn: d.u64()?,
            lineages_retrained: d.u64()?,
            est_seconds: d.f64()?,
            est_joules: d.f64()?,
            deferred: d.bool()?,
        })
    }
}

/// Mirror of [`BatchReport`](crate::unlearning::BatchReport).
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct BatchReportRec {
    pub requests: u64,
    pub rsn: u64,
    pub lineages_retrained: u64,
    pub retrains_coalesced: u64,
    pub oldest_queued_ticks: u64,
    pub est_seconds: f64,
    pub est_joules: f64,
    pub deferred: bool,
}

impl BatchReportRec {
    fn encode(&self, e: &mut Enc) {
        e.u64(self.requests);
        e.u64(self.rsn);
        e.u64(self.lineages_retrained);
        e.u64(self.retrains_coalesced);
        e.u64(self.oldest_queued_ticks);
        e.f64(self.est_seconds);
        e.f64(self.est_joules);
        e.bool(self.deferred);
    }

    fn decode(d: &mut Dec) -> DecodeResult<BatchReportRec> {
        Ok(BatchReportRec {
            requests: d.u64()?,
            rsn: d.u64()?,
            lineages_retrained: d.u64()?,
            retrains_coalesced: d.u64()?,
            oldest_queued_ticks: d.u64()?,
            est_seconds: d.f64()?,
            est_joules: d.f64()?,
            deferred: d.bool()?,
        })
    }
}

/// Carryover plan state after a window transition: one entry per parked
/// lineage — `(lineage, poisoned segments, requests touching)`.
#[derive(Clone, Debug, Default, PartialEq, Eq)]
pub struct PlanRec {
    pub lineages: Vec<(u64, Vec<u64>, u64)>,
    pub requests: u64,
}

impl PlanRec {
    fn encode(&self, e: &mut Enc) {
        e.u64(self.lineages.len() as u64);
        for (l, segs, touching) in &self.lineages {
            e.u64(*l);
            e.words(segs);
            e.u64(*touching);
        }
        e.u64(self.requests);
    }

    fn decode(d: &mut Dec) -> DecodeResult<PlanRec> {
        let n = d.count()?;
        let mut lineages = Vec::with_capacity(n.min(1 << 12));
        for _ in 0..n {
            let l = d.u64()?;
            let segs = d.words()?;
            let touching = d.u64()?;
            lineages.push((l, segs, touching));
        }
        Ok(PlanRec { lineages, requests: d.u64()? })
    }
}

/// Receipt bookkeeping of a request travelling in a carryover plan.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct MetaRec {
    pub user: u32,
    pub round: u32,
    pub arrival_tick: u64,
}

pub(crate) fn encode_carryover(e: &mut Enc, c: &Option<(PlanRec, Vec<MetaRec>)>) {
    match c {
        None => e.bool(false),
        Some((plan, metas)) => {
            e.bool(true);
            plan.encode(e);
            e.u64(metas.len() as u64);
            for m in metas {
                e.u32(m.user);
                e.u32(m.round);
                e.u64(m.arrival_tick);
            }
        }
    }
}

pub(crate) fn decode_carryover(d: &mut Dec) -> DecodeResult<Option<(PlanRec, Vec<MetaRec>)>> {
    if !d.bool()? {
        return Ok(None);
    }
    let plan = PlanRec::decode(d)?;
    let n = d.count()?;
    let mut metas = Vec::with_capacity(n.min(1 << 12));
    for _ in 0..n {
        metas.push(MetaRec { user: d.u32()?, round: d.u32()?, arrival_tick: d.u64()? });
    }
    Ok(Some((plan, metas)))
}

// ---------------------------------------------------------------------------
// Transition records
// ---------------------------------------------------------------------------

/// One training round ([`UnlearningService::ingest_round`]): clock +1,
/// recorded placements into the lineages, store admissions, metric posts.
///
/// [`UnlearningService::ingest_round`]: crate::unlearning::UnlearningService::ingest_round
#[derive(Clone, Debug, PartialEq)]
pub struct RoundRec {
    pub round: u32,
    pub placements: Vec<PlacementRecord>,
    pub store_ops: Vec<StoreOpRec>,
    /// The `accuracy_by_round` entry this round pushed.
    pub accuracy: Option<f64>,
    pub metrics: MetricsPost,
    pub partitioner_state: Vec<u64>,
    pub policy_state: Vec<u64>,
}

/// One FCFS-served (or newly deferred) request.
#[derive(Clone, Debug, PartialEq)]
pub struct ServeRec {
    /// The queue head was consumed (false for a deferral).
    pub popped: bool,
    pub store_ops: Vec<StoreOpRec>,
    pub battery: Option<BatteryPost>,
    pub metrics: MetricsPost,
    pub latency: Option<LatencyRecord>,
    pub report: SvcReportRec,
    pub head_deferral_logged: bool,
    pub policy_state: Vec<u64>,
}

/// One batched window transition: executed, starved-and-parked, or a
/// carryover merge.
#[derive(Clone, Debug, PartialEq)]
pub struct WindowRec {
    /// Requests popped from the queue front into this window.
    pub drained: u64,
    pub store_ops: Vec<StoreOpRec>,
    pub battery: Option<BatteryPost>,
    pub metrics: MetricsPost,
    pub latency: Vec<LatencyRecord>,
    pub report: Option<BatchReportRec>,
    pub carryover: Option<(PlanRec, Vec<MetaRec>)>,
    pub head_deferral_logged: bool,
    pub policy_state: Vec<u64>,
}

/// A durable state transition of the unlearning service.
#[derive(Clone, Debug, PartialEq)]
pub enum Event {
    /// Service clock advanced by `ticks`.
    Advance { ticks: u64 },
    /// Battery harvested; absolute post-state.
    Harvest { battery: Option<BatteryPost> },
    /// Request accepted into the queue (log-before-ack).
    Submit(ReqRecord),
    Round(Box<RoundRec>),
    Serve(Box<ServeRec>),
    Window(Box<WindowRec>),
}

impl Event {
    /// Encode with the log sequence number prepended. `spill` controls
    /// whether checkpoint payload bytes ride along.
    pub fn encode(&self, seq: u64, spill: bool) -> Vec<u8> {
        let mut e = Enc::new();
        e.u64(seq);
        match self {
            Event::Advance { ticks } => {
                e.u8(0);
                e.u64(*ticks);
            }
            Event::Harvest { battery } => {
                e.u8(1);
                encode_battery(&mut e, battery);
            }
            Event::Submit(r) => {
                e.u8(2);
                r.encode(&mut e);
            }
            Event::Round(r) => {
                e.u8(3);
                e.u32(r.round);
                e.u64(r.placements.len() as u64);
                for p in &r.placements {
                    e.u64(p.block);
                    e.u32(p.user);
                    e.u64(p.shard);
                    e.u64(p.samples);
                }
                encode_ops(&mut e, &r.store_ops, spill);
                match r.accuracy {
                    None => e.bool(false),
                    Some(a) => {
                        e.bool(true);
                        e.f64(a);
                    }
                }
                r.metrics.encode(&mut e);
                e.words(&r.partitioner_state);
                e.words(&r.policy_state);
            }
            Event::Serve(r) => {
                e.u8(4);
                e.bool(r.popped);
                encode_ops(&mut e, &r.store_ops, spill);
                encode_battery(&mut e, &r.battery);
                r.metrics.encode(&mut e);
                match &r.latency {
                    None => e.bool(false),
                    Some(l) => {
                        e.bool(true);
                        l.encode(&mut e);
                    }
                }
                r.report.encode(&mut e);
                e.bool(r.head_deferral_logged);
                e.words(&r.policy_state);
            }
            Event::Window(r) => {
                e.u8(5);
                e.u64(r.drained);
                encode_ops(&mut e, &r.store_ops, spill);
                encode_battery(&mut e, &r.battery);
                r.metrics.encode(&mut e);
                e.u64(r.latency.len() as u64);
                for l in &r.latency {
                    l.encode(&mut e);
                }
                match &r.report {
                    None => e.bool(false),
                    Some(b) => {
                        e.bool(true);
                        b.encode(&mut e);
                    }
                }
                encode_carryover(&mut e, &r.carryover);
                e.bool(r.head_deferral_logged);
                e.words(&r.policy_state);
            }
        }
        e.buf
    }

    /// Decode one frame payload. Returns the sequence number and event;
    /// spilled checkpoint payloads are re-linked through `dedup`.
    pub fn decode(payload: &[u8], dedup: &mut PayloadDedup) -> DecodeResult<(u64, Event)> {
        let mut d = Dec::new(payload);
        let seq = d.u64()?;
        let ev = match d.u8()? {
            0 => Event::Advance { ticks: d.u64()? },
            1 => Event::Harvest { battery: decode_battery(&mut d)? },
            2 => Event::Submit(ReqRecord::decode(&mut d)?),
            3 => {
                let round = d.u32()?;
                let n = d.count()?;
                let mut placements = Vec::with_capacity(n.min(1 << 12));
                for _ in 0..n {
                    placements.push(PlacementRecord {
                        block: d.u64()?,
                        user: d.u32()?,
                        shard: d.u64()?,
                        samples: d.u64()?,
                    });
                }
                let store_ops = decode_ops(&mut d, dedup)?;
                let accuracy = if d.bool()? { Some(d.f64()?) } else { None };
                let metrics = MetricsPost::decode(&mut d)?;
                let partitioner_state = d.words()?;
                let policy_state = d.words()?;
                Event::Round(Box::new(RoundRec {
                    round,
                    placements,
                    store_ops,
                    accuracy,
                    metrics,
                    partitioner_state,
                    policy_state,
                }))
            }
            4 => {
                let popped = d.bool()?;
                let store_ops = decode_ops(&mut d, dedup)?;
                let battery = decode_battery(&mut d)?;
                let metrics = MetricsPost::decode(&mut d)?;
                let latency =
                    if d.bool()? { Some(LatencyRecord::decode(&mut d)?) } else { None };
                let report = SvcReportRec::decode(&mut d)?;
                let head_deferral_logged = d.bool()?;
                let policy_state = d.words()?;
                Event::Serve(Box::new(ServeRec {
                    popped,
                    store_ops,
                    battery,
                    metrics,
                    latency,
                    report,
                    head_deferral_logged,
                    policy_state,
                }))
            }
            5 => {
                let drained = d.u64()?;
                let store_ops = decode_ops(&mut d, dedup)?;
                let battery = decode_battery(&mut d)?;
                let metrics = MetricsPost::decode(&mut d)?;
                let n = d.count()?;
                let mut latency = Vec::with_capacity(n.min(1 << 12));
                for _ in 0..n {
                    latency.push(LatencyRecord::decode(&mut d)?);
                }
                let report =
                    if d.bool()? { Some(BatchReportRec::decode(&mut d)?) } else { None };
                let carryover = decode_carryover(&mut d)?;
                let head_deferral_logged = d.bool()?;
                let policy_state = d.words()?;
                Event::Window(Box::new(WindowRec {
                    drained,
                    store_ops,
                    battery,
                    metrics,
                    latency,
                    report,
                    carryover,
                    head_deferral_logged,
                    policy_state,
                }))
            }
            t => return Err(format!("unknown event tag {t}")),
        };
        d.finished()?;
        Ok((seq, ev))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::prng::Rng;
    use crate::runtime::codec::{CodecMode, TensorCodec};
    use crate::runtime::HostTensor;
    use crate::testkit::forall;

    fn roundtrip(ev: &Event, seq: u64, spill: bool) -> Event {
        let bytes = ev.encode(seq, spill);
        let mut dedup = PayloadDedup::new();
        let (got_seq, got) = Event::decode(&bytes, &mut dedup).expect("decode");
        assert_eq!(got_seq, seq);
        got
    }

    fn rand_metrics(rng: &mut Rng) -> MetricsPost {
        MetricsPost {
            warm_retrains: rng.below(100),
            scratch_retrains: rng.below(100),
            lineages_retrained: rng.below(100),
            prunes: rng.below(1000),
            energy_joules: rng.f64() * 1e4,
            ckpts_stored: rng.below(500),
            ckpts_replaced: rng.below(500),
            ckpts_rejected: rng.below(500),
            ckpts_invalidated: rng.below(500),
            batches: rng.below(40),
            batched_requests: rng.below(400),
            retrains_coalesced: rng.below(400),
            round_slots: rng.below(20),
            rsn_last: rng.below(100_000),
            requests_last: rng.below(50),
        }
    }

    fn rand_ops(rng: &mut Rng) -> Vec<StoreOpRec> {
        (0..rng.range(0, 4))
            .map(|i| match rng.range(0, 3) {
                0 => StoreOpRec::Store {
                    id: i as u64 + rng.below(100),
                    lineage: rng.below(8),
                    round: rng.below(20) as u32,
                    covered: rng.below(20) as u32,
                    size_bytes: rng.below(1 << 20),
                    payload: None,
                    event: match rng.range(0, 4) {
                        0 => StoreEvRec::Stored { slot: rng.below(16) },
                        1 => StoreEvRec::Replaced {
                            slot: rng.below(16),
                            evicted: rng.below(100),
                        },
                        2 => StoreEvRec::Evicted {
                            slot: rng.below(16),
                            victims: (0..rng.range(1, 4)).map(|_| rng.below(100)).collect(),
                        },
                        _ => StoreEvRec::Rejected,
                    },
                },
                1 => StoreOpRec::SkipReject { id: rng.below(1000) },
                _ => StoreOpRec::Invalidate {
                    ids: (0..rng.range(0, 5)).map(|_| rng.below(1000)).collect(),
                },
            })
            .collect()
    }

    fn rand_event(rng: &mut Rng) -> Event {
        match rng.range(0, 6) {
            0 => Event::Advance { ticks: rng.below(1 << 30) },
            1 => Event::Harvest {
                battery: rng
                    .chance(0.7)
                    .then(|| BatteryPost { charge_j: rng.f64() * 7.2e4, brownouts: rng.below(9) }),
            },
            2 => Event::Submit(ReqRecord {
                user: rng.below(1000) as u32,
                round: rng.below(30) as u32,
                arrival_tick: rng.below(1000),
                parts: (0..rng.range(0, 6))
                    .map(|_| (rng.below(10_000), rng.below(500)))
                    .collect(),
            }),
            3 => Event::Round(Box::new(RoundRec {
                round: rng.below(30) as u32,
                placements: (0..rng.range(0, 8))
                    .map(|_| PlacementRecord {
                        block: rng.below(10_000),
                        user: rng.below(1000) as u32,
                        shard: rng.below(8),
                        samples: rng.below(500),
                    })
                    .collect(),
                store_ops: rand_ops(rng),
                accuracy: rng.chance(0.3).then(|| rng.f64()),
                metrics: rand_metrics(rng),
                partitioner_state: (0..rng.range(0, 12)).map(|_| rng.next_u64()).collect(),
                policy_state: (0..rng.range(0, 6)).map(|_| rng.next_u64()).collect(),
            })),
            4 => Event::Serve(Box::new(ServeRec {
                popped: rng.chance(0.8),
                store_ops: rand_ops(rng),
                battery: rng
                    .chance(0.5)
                    .then(|| BatteryPost { charge_j: rng.f64() * 100.0, brownouts: rng.below(5) }),
                metrics: rand_metrics(rng),
                latency: rng.chance(0.8).then(|| LatencyRecord {
                    user: rng.below(100) as u32,
                    round: rng.below(20) as u32,
                    queued_ticks: rng.below(50),
                    slo_met: rng.chance(0.9),
                }),
                report: SvcReportRec {
                    user: rng.below(100) as u32,
                    round: rng.below(20) as u32,
                    rsn: rng.below(100_000),
                    lineages_retrained: rng.below(8),
                    est_seconds: rng.f64() * 100.0,
                    est_joules: rng.f64() * 1000.0,
                    deferred: rng.chance(0.2),
                },
                head_deferral_logged: rng.chance(0.2),
                policy_state: (0..rng.range(0, 6)).map(|_| rng.next_u64()).collect(),
            })),
            _ => Event::Window(Box::new(WindowRec {
                drained: rng.below(20),
                store_ops: rand_ops(rng),
                battery: rng
                    .chance(0.5)
                    .then(|| BatteryPost { charge_j: rng.f64() * 100.0, brownouts: rng.below(5) }),
                metrics: rand_metrics(rng),
                latency: (0..rng.range(0, 5))
                    .map(|_| LatencyRecord {
                        user: rng.below(100) as u32,
                        round: rng.below(20) as u32,
                        queued_ticks: rng.below(50),
                        slo_met: rng.chance(0.9),
                    })
                    .collect(),
                report: rng.chance(0.8).then(|| BatchReportRec {
                    requests: rng.below(20),
                    rsn: rng.below(100_000),
                    lineages_retrained: rng.below(8),
                    retrains_coalesced: rng.below(20),
                    oldest_queued_ticks: rng.below(60),
                    est_seconds: rng.f64() * 100.0,
                    est_joules: rng.f64() * 1000.0,
                    deferred: rng.chance(0.2),
                }),
                carryover: rng.chance(0.4).then(|| {
                    (
                        PlanRec {
                            lineages: (0..rng.range(1, 4))
                                .map(|l| {
                                    (
                                        l as u64,
                                        (0..rng.range(1, 5)).map(|_| rng.below(20)).collect(),
                                        rng.below(5) + 1,
                                    )
                                })
                                .collect(),
                            requests: rng.below(10),
                        },
                        (0..rng.range(0, 4))
                            .map(|_| MetaRec {
                                user: rng.below(100) as u32,
                                round: rng.below(20) as u32,
                                arrival_tick: rng.below(100),
                            })
                            .collect(),
                    )
                }),
                head_deferral_logged: rng.chance(0.2),
                policy_state: (0..rng.range(0, 6)).map(|_| rng.next_u64()).collect(),
            })),
        }
    }

    #[test]
    fn prop_events_roundtrip() {
        forall(
            0xE7E27,
            150,
            |rng, _| {
                let seq = rng.next_u64();
                (seq, rand_event(rng))
            },
            |(seq, ev)| {
                let got = roundtrip(ev, *seq, false);
                if got != *ev {
                    return Err(format!("round-trip mismatch: {got:?}"));
                }
                Ok(())
            },
        );
    }

    #[test]
    fn decode_rejects_garbage_and_trailing_bytes() {
        let mut dedup = PayloadDedup::new();
        assert!(Event::decode(b"", &mut dedup).is_err());
        assert!(Event::decode(&[0; 8], &mut dedup).is_err()); // seq, no tag
        let mut bytes = Event::Advance { ticks: 7 }.encode(3, false);
        bytes.push(0);
        assert!(Event::decode(&bytes, &mut dedup).is_err(), "trailing byte");
        bytes.truncate(bytes.len() - 2);
        assert!(Event::decode(&bytes, &mut dedup).is_err(), "truncated");
        // Unknown tag.
        let mut e = Enc::new();
        e.u64(0);
        e.u8(99);
        assert!(Event::decode(&e.buf, &mut dedup).is_err());
    }

    /// Spilled payload chains re-establish `Arc` sharing across events:
    /// two checkpoints whose deltas pinned the same parent share one
    /// reconstructed parent allocation after decode.
    #[test]
    fn spilled_payload_chains_share_parents_on_decode() {
        let codec = TensorCodec::new(CodecMode::Delta);
        let base = vec![HostTensor::from_fn(&[96], |i| (i as f32).cos())];
        let parent = Arc::new(codec.encode(&base, None));
        let mut v1 = base.clone();
        v1[0].data[3] = 5.0;
        let child_a = Arc::new(codec.encode(&v1, Some(&parent)));
        let mut v2 = base.clone();
        v2[0].data[9] = -2.0;
        let child_b = Arc::new(codec.encode(&v2, Some(&parent)));

        let op = |p: &Arc<EncodedParams>, id: u64| StoreOpRec::Store {
            id,
            lineage: 0,
            round: 1,
            covered: 1,
            size_bytes: p.size_bytes(),
            payload: Some(p.clone()),
            event: StoreEvRec::Stored { slot: id },
        };
        let ev_a = Event::Serve(Box::new(ServeRec {
            popped: true,
            store_ops: vec![op(&child_a, 0)],
            battery: None,
            metrics: MetricsPost::default(),
            latency: None,
            report: SvcReportRec {
                user: 0,
                round: 1,
                rsn: 0,
                lineages_retrained: 0,
                est_seconds: 0.0,
                est_joules: 0.0,
                deferred: false,
            },
            head_deferral_logged: false,
            policy_state: vec![],
        }));
        let ev_b = match &ev_a {
            Event::Serve(r) => {
                let mut r2 = (**r).clone();
                r2.store_ops = vec![op(&child_b, 1)];
                Event::Serve(Box::new(r2))
            }
            _ => unreachable!(),
        };

        let mut dedup = PayloadDedup::new();
        let (_, got_a) = Event::decode(&ev_a.encode(0, true), &mut dedup).unwrap();
        let (_, got_b) = Event::decode(&ev_b.encode(1, true), &mut dedup).unwrap();
        let payload_of = |ev: &Event| match ev {
            Event::Serve(r) => match &r.store_ops[0] {
                StoreOpRec::Store { payload, .. } => payload.clone().unwrap(),
                _ => panic!("expected store op"),
            },
            _ => panic!("expected serve"),
        };
        let (pa, pb) = (payload_of(&got_a), payload_of(&got_b));
        assert_eq!(pa.decode(), v1, "payload A decodes bit-exact");
        assert_eq!(pb.decode(), v2, "payload B decodes bit-exact");
        let (parent_a, parent_b) =
            (pa.parent().expect("delta").clone(), pb.parent().expect("delta").clone());
        assert!(
            Arc::ptr_eq(&parent_a, &parent_b),
            "shared parent must be one allocation after recovery"
        );
        assert_eq!(parent_a.uid(), parent.uid());
        assert_eq!(parent_a.decode(), base);
        // Without spill the payload stays behind (log mode).
        let mut dedup = PayloadDedup::new();
        let (_, lean) = Event::decode(&ev_a.encode(0, false), &mut dedup).unwrap();
        match &lean {
            Event::Serve(r) => match &r.store_ops[0] {
                StoreOpRec::Store { payload, size_bytes, .. } => {
                    assert!(payload.is_none());
                    assert_eq!(*size_bytes, child_a.size_bytes(), "size survives");
                }
                _ => panic!("expected store op"),
            },
            _ => panic!("expected serve"),
        }
    }
}
