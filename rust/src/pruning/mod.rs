//! Pruning schedules: RCMP (iterative prune-and-retrain) vs OMP (one-shot),
//! plus the size accounting used by the cost path.
//!
//! The actual tensor pruning runs through the Layer-1 Pallas kernel (the
//! `<variant>/prune` artifact); this module decides *when* and *how hard*
//! to prune during a training run, and what the stored checkpoint size is.

/// How a system prunes its sub-models.
#[derive(Clone, Copy, Debug, PartialEq)]
pub enum PruneSchedule {
    /// No pruning (SISA / ARCANE).
    None,
    /// RCMP: interleave pruning with training, stepping the keep fraction
    /// geometrically from 1.0 down to `keep` over `steps` prune passes,
    /// fine-tuning between passes (paper §4.2, Fig. 4).
    Iterative { keep: f64, steps: u32 },
    /// OMP: a single magnitude-prune at the end of training.
    OneShot { keep: f64 },
}

impl PruneSchedule {
    /// Final keep fraction of prunable weights.
    pub fn final_keep(&self) -> f64 {
        match self {
            PruneSchedule::None => 1.0,
            PruneSchedule::Iterative { keep, .. } | PruneSchedule::OneShot { keep } => *keep,
        }
    }

    /// Keep fraction to apply after prune pass `i` (0-based) of `total`
    /// passes in this training run. For `OneShot` only the last pass acts.
    ///
    /// The iterative (RCMP) schedule reaches the target keep one pass
    /// *early* so the final pass fine-tunes the pruned structure; the very
    /// last pass re-applies the target keep to refresh sparsity (plain-SGD
    /// fine-tuning regrows pruned weights — they restart near zero, so the
    /// refresh removes mostly the regrown mass: the paper's
    /// prune-then-fine-tune loop of Fig. 4).
    pub fn keep_at(&self, pass: u32, total_passes: u32) -> Option<f64> {
        let total = total_passes.max(1);
        match self {
            PruneSchedule::None => None,
            PruneSchedule::OneShot { keep } => {
                (pass + 1 == total).then_some(*keep)
            }
            PruneSchedule::Iterative { keep, steps } => {
                if total == 1 {
                    return (pass == 0).then_some(*keep);
                }
                // Geometric descent over the last `steps` passes before the
                // final fine-tune pass, then a sparsity refresh at the end.
                let steps = (*steps).min(total - 1).max(1);
                if pass + 1 == total {
                    return Some(*keep); // refresh after fine-tune
                }
                let first_active = (total - 1) - steps;
                if pass < first_active {
                    return None;
                }
                let i = pass - first_active + 1; // 1..=steps
                Some(keep.powf(i as f64 / steps as f64))
            }
        }
    }

    /// Number of prune kernel invocations a training run with
    /// `total_passes` checkpoints will execute (energy accounting).
    pub fn prune_ops(&self, total_passes: u32) -> u64 {
        let total = total_passes.max(1);
        (0..total).filter(|p| self.keep_at(*p, total).is_some()).count() as u64
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn final_keep_values() {
        assert_eq!(PruneSchedule::None.final_keep(), 1.0);
        assert_eq!(PruneSchedule::OneShot { keep: 0.05 }.final_keep(), 0.05);
        assert_eq!(PruneSchedule::Iterative { keep: 0.3, steps: 4 }.final_keep(), 0.3);
    }

    #[test]
    fn one_shot_fires_only_at_end() {
        let s = PruneSchedule::OneShot { keep: 0.3 };
        assert_eq!(s.keep_at(0, 4), None);
        assert_eq!(s.keep_at(2, 4), None);
        assert_eq!(s.keep_at(3, 4), Some(0.3));
        assert_eq!(s.prune_ops(4), 1);
    }

    #[test]
    fn iterative_steps_down_geometrically_then_refreshes() {
        let s = PruneSchedule::Iterative { keep: 0.3, steps: 3 };
        let keeps: Vec<f64> = (0..5).filter_map(|p| s.keep_at(p, 5)).collect();
        // 3 descending passes, a fine-tune gap, then the refresh pass.
        assert_eq!(keeps.len(), 4);
        assert!(keeps[0] > keeps[1] && keeps[1] > keeps[2]);
        assert!((keeps[2] - 0.3).abs() < 1e-12);
        assert!((keeps[3] - 0.3).abs() < 1e-12);
        // Constant prune *fraction* per step (geometric schedule).
        let r1 = keeps[1] / keeps[0];
        let r2 = keeps[2] / keeps[1];
        assert!((r1 - r2).abs() < 1e-9);
        assert_eq!(s.prune_ops(5), 4);
    }

    #[test]
    fn iterative_single_pass_prunes_once_at_target() {
        let s = PruneSchedule::Iterative { keep: 0.3, steps: 10 };
        assert_eq!(s.keep_at(0, 1), Some(0.3));
        assert_eq!(s.prune_ops(1), 1);
        // Two passes: descend to target at pass 0, refresh at pass 1.
        let keeps: Vec<f64> = (0..2).filter_map(|p| s.keep_at(p, 2)).collect();
        assert_eq!(keeps.len(), 2);
        assert!((keeps[1] - 0.3).abs() < 1e-12);
    }

    #[test]
    fn none_never_fires() {
        let s = PruneSchedule::None;
        for p in 0..5 {
            assert_eq!(s.keep_at(p, 5), None);
        }
        assert_eq!(s.prune_ops(5), 0);
    }
}
