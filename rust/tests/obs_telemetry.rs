//! Observability-layer properties, end to end through the load harness:
//!
//! * same seed ⇒ byte-identical Chrome-trace exports, for the unsharded
//!   service and for the threaded fleet (logical ticks only — no wall
//!   clock ever enters a span);
//! * tracing is observation-only: the full deterministic `LoadReport`
//!   (receipts, histograms, telemetry counters) is byte-identical with
//!   spans on and off;
//! * cross-process parenting: worker-lane root spans in a fleet trace
//!   carry the front-end span that dispatched them as their parent;
//! * the tick-budget fold attributes ≥95% of in-span time to named
//!   phases and recovers the harness's phase markers from the export.
//!
//! Ring-buffer wrap behavior and span-id determinism are unit-tested in
//! `cause::obs`; this file pins the integration surface the `obs`
//! binary, `bench_load`, and the soak all share.

use cause::load::{corpus, run_open_loop, OpenLoopCfg, Scenario};
use cause::obs::budget;
use cause::util::Json;

/// Pull one corpus member by its gate name.
fn scenario(name: &str) -> Box<dyn Scenario> {
    corpus()
        .into_iter()
        .find(|s| s.name() == name)
        .unwrap_or_else(|| panic!("scenario {name} not in corpus"))
}

/// A short, non-saturating run shape shared by every test here.
fn cfg(obs: bool) -> OpenLoopCfg {
    OpenLoopCfg {
        offered_per_tick: 1.0,
        ticks: 12,
        tail_ticks: 128,
        seed: 0x0b5_7e57,
        obs,
    }
}

/// The exported trace document of one traced run (panics if absent).
fn trace_of(name: &str) -> Json {
    let report = run_open_loop(scenario(name).as_ref(), &cfg(true)).unwrap();
    report.trace.expect("obs run must carry a trace export")
}

#[test]
fn same_seed_trace_exports_are_byte_identical() {
    // One single-node scenario, one threaded two-worker fleet: virtual
    // timestamps and stable merge order make even the fleet's trace a
    // pure function of the seed.
    for name in ["gdpr_storm", "iot_fleet_churn"] {
        let a = trace_of(name).to_pretty();
        let b = trace_of(name).to_pretty();
        assert_eq!(a, b, "{name}: trace export diverged across same-seed runs");
        let events = Json::parse(&a)
            .unwrap()
            .at(&["traceEvents"])
            .and_then(Json::as_arr)
            .map(|a| a.len())
            .unwrap_or(0);
        assert!(events > 0, "{name}: traced run exported no events");
    }
}

#[test]
fn tracing_is_observation_only() {
    for name in ["gdpr_storm", "iot_fleet_churn"] {
        let off = run_open_loop(scenario(name).as_ref(), &cfg(false)).unwrap();
        let on = run_open_loop(scenario(name).as_ref(), &cfg(true)).unwrap();
        assert!(off.trace.is_none(), "{name}: untraced run grew a trace");
        assert!(on.trace.is_some(), "{name}: traced run lost its trace");
        // The full deterministic report — served counts, trace digest,
        // latency histogram, registry telemetry — must not move by a
        // byte when spans turn on.
        assert_eq!(
            off.to_json().to_string(),
            on.to_json().to_string(),
            "{name}: tracing perturbed the load report"
        );
    }
}

#[test]
fn fleet_trace_parents_worker_roots_to_front_end() {
    let doc = trace_of("iot_fleet_churn");
    let (spans, _) = budget::spans_from_chrome(&doc).unwrap();
    let front_ids: Vec<u64> =
        spans.iter().filter(|s| s.lane == 0).map(|s| s.id).collect();
    assert!(!front_ids.is_empty(), "no front-end spans in fleet trace");
    assert!(
        spans.iter().any(|s| s.lane > 1),
        "two-worker fleet trace shows only one worker lane"
    );
    // Worker drains are dispatched by the front-end: their root spans
    // must link back to a front-end span id (a cross-lane parent).
    let adopted: Vec<&budget::BudgetSpan> = spans
        .iter()
        .filter(|s| s.lane != 0 && s.parent != 0 && front_ids.contains(&s.parent))
        .collect();
    assert!(
        !adopted.is_empty(),
        "no worker span carries a front-end parent — cross-process link lost"
    );
    assert!(
        adopted.iter().any(|s| s.name.starts_with("drain")),
        "adopted worker spans exist but none is a drain root: {:?}",
        adopted.iter().map(|s| s.name.as_str()).collect::<Vec<_>>()
    );
}

#[test]
fn budget_attributes_in_span_time_and_recovers_markers() {
    for name in ["gdpr_storm", "iot_fleet_churn"] {
        let doc = trace_of(name);
        let (spans, markers) = budget::spans_from_chrome(&doc).unwrap();
        let b = budget::compute(&spans);
        assert!(b.root_us > 0, "{name}: no rooted span time to attribute");
        assert!(
            b.attributed_us * 100 >= b.root_us * 95,
            "{name}: only {}/{} us attributed to named phases",
            b.attributed_us,
            b.root_us
        );
        for marker in ["phase:arrivals", "phase:tail"] {
            assert!(
                markers.iter().any(|(m, n)| m == marker && *n > 0),
                "{name}: export lost the {marker} marker: {markers:?}"
            );
        }
        // The render is total: every row and the footer line appear.
        let table = budget::render(&b, &markers);
        assert!(table.contains("% attributed"));
        for row in &b.rows {
            assert!(table.contains(&row.name), "row {} missing", row.name);
        }
    }
}
