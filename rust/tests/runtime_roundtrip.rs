//! Integration test: the full python-AOT -> rust-PJRT round trip.
//!
//! Requires `make artifacts` to have produced `artifacts/manifest.txt`.
//! Skips (with a loud message) when artifacts are missing so `cargo test`
//! stays green on a fresh checkout; `make test` always builds them first.

use std::rc::Rc;

use cause::runtime::{PruneSession, Runtime, TrainSession};

fn runtime() -> Option<Rc<Runtime>> {
    let dir = std::path::Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts");
    if !dir.join("manifest.txt").exists() {
        eprintln!("SKIP runtime_roundtrip: no artifacts (run `make artifacts`)");
        return None;
    }
    Some(Rc::new(Runtime::new(dir).expect("runtime")))
}

/// Deterministic pseudo-random training batch with learnable structure:
/// class = sign pattern of the first feature block.
fn toy_batch(n: usize, features: usize, seed: u64) -> (Vec<f32>, Vec<f32>) {
    let mut state = seed.wrapping_mul(0x9e3779b97f4a7c15).wrapping_add(1);
    let mut next = move || {
        state ^= state << 13;
        state ^= state >> 7;
        state ^= state << 17;
        (state >> 11) as f32 / (1u64 << 53) as f32
    };
    let mut xs = vec![0.0f32; n * features];
    let mut ys = vec![0.0f32; n];
    for r in 0..n {
        let class = r % 2;
        ys[r] = class as f32;
        for c in 0..features {
            let base = if class == 0 { 0.5 } else { -0.5 };
            xs[r * features + c] = base + 0.1 * (next() - 0.5);
        }
    }
    (xs, ys)
}

#[test]
fn train_predict_prune_roundtrip() {
    let Some(rt) = runtime() else { return };
    let variant = "mobilenetv2_c10";
    if rt.manifest().get(&format!("{variant}/train_step")).is_err() {
        eprintln!("SKIP: variant {variant} not lowered");
        return;
    }

    let mut sess = TrainSession::init(rt.clone(), variant, 7).expect("init");
    assert_eq!(sess.feature_dim(), 3072);
    let (xs, ys) = toy_batch(sess.batch_size(), sess.feature_dim(), 42);

    // Loss must drop substantially on a linearly-separable toy batch.
    let first = sess.step(&xs, &ys, 0.05).expect("step");
    let mut last = first;
    for _ in 0..20 {
        last = sess.step(&xs, &ys, 0.05).expect("step");
    }
    assert!(
        last < first * 0.5,
        "loss did not drop: first={first} last={last}"
    );

    // Predictions should now match the toy labels.
    let logits = sess.logits(&xs, ys.len()).expect("logits");
    let mut correct = 0;
    for (row, y) in logits.iter().zip(&ys) {
        let argmax = row
            .iter()
            .enumerate()
            .max_by(|a, b| a.1.partial_cmp(b.1).unwrap())
            .unwrap()
            .0;
        if argmax == *y as usize {
            correct += 1;
        }
    }
    assert!(
        correct * 10 >= ys.len() * 9,
        "accuracy too low: {correct}/{}",
        ys.len()
    );

    // Pruning at keep=0.3 zeroes ~70% of the big weight matrices.
    let before: usize = sess.params().iter().map(|p| p.nonzero_count()).sum();
    sess.prune(0.3).expect("prune");
    let after: usize = sess.params().iter().map(|p| p.nonzero_count()).sum();
    assert!(
        (after as f64) < (before as f64) * 0.45,
        "prune did not sparsify: {before} -> {after}"
    );

    // Pruned model must still train (RCMP fine-tuning path).
    let resumed = sess.step(&xs, &ys, 0.05).expect("step after prune");
    assert!(resumed.is_finite());
}

#[test]
fn padded_rows_do_not_change_training() {
    let Some(rt) = runtime() else { return };
    let variant = "mobilenetv2_c10";
    if rt.manifest().get(&format!("{variant}/train_step")).is_err() {
        return;
    }
    let mut a = TrainSession::init(rt.clone(), variant, 3).unwrap();
    let mut b = TrainSession::init(rt.clone(), variant, 3).unwrap();
    let full = a.batch_size();
    let (xs, ys) = toy_batch(full, a.feature_dim(), 1);
    let half = full / 2;

    // Session A sees only `half` rows; session B sees the same rows —
    // the padding convention must make them identical.
    let la = a.step(&xs[..half * a.feature_dim()], &ys[..half], 0.1).unwrap();
    let lb = b.step(&xs[..half * b.feature_dim()], &ys[..half], 0.1).unwrap();
    assert!((la - lb).abs() < 1e-6);
    for (pa, pb) in a.params().iter().zip(b.params()) {
        assert_eq!(pa, pb);
    }
}

#[test]
fn stateless_prune_session_matches_member_prune() {
    let Some(rt) = runtime() else { return };
    let variant = "mobilenetv2_c10";
    if rt.manifest().get(&format!("{variant}/prune")).is_err() {
        return;
    }
    let sess = TrainSession::init(rt.clone(), variant, 11).unwrap();
    let pruner = PruneSession { rt: rt.clone(), variant: variant.into() };
    let pruned = pruner.prune(sess.params(), 0.5).unwrap();
    let kept: usize = pruned.iter().map(|p| p.nonzero_count()).sum();
    let total: usize = sess.params().iter().map(|p| p.len()).sum();
    assert!(kept < total, "pruning kept everything");
    // Idempotence: pruning an already-pruned model at the same rate is a no-op.
    let again = pruner.prune(&pruned, 0.5).unwrap();
    assert_eq!(pruned, again);
}
