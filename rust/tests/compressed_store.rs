//! Byte-budget store semantics: differential equivalence against slot
//! mode, and the compression win it exists for.
//!
//! * With unit-size checkpoints (or any uniform size that divides the
//!   budget), byte metering must replay slot metering **receipt for
//!   receipt** — events, stats, byte counters, index lookups. That is the
//!   degenerate point proving the refactor changed no baseline behavior.
//! * At keep=1.0 the cost backend's checkpoints are uniform dense-size, so
//!   a whole engine run (CAUSE/FiboR and SISA/NoReplace) must produce
//!   identical receipts under either meter.
//! * At keep=0.3 with real tensors (`HostTrainer`), the byte meter must
//!   hold ≥2x the checkpoints in the same C_m and replay fewer samples
//!   (lower RSN) — the paper's Table 2 claim made real.

use cause::config::ExperimentConfig;
use cause::coordinator::engine::EvalPolicy;
use cause::coordinator::system::SystemVariant;
use cause::coordinator::Engine;
use cause::data::dataset::{EdgePopulation, PopulationConfig};
use cause::data::trace::{RequestTrace, TraceConfig};
use cause::memory::{ModelStore, StoreEvent, StoreMeter};
use cause::memory::store::{CapacityMode, Checkpoint, CheckpointId};
use cause::replacement::{FiboR, NoReplace};
use cause::testkit::forall_prefixes;
use cause::training::host::dense_upper_bound;
use cause::training::{CostTrainer, HostTrainer, HostTrainerConfig, Trainer};

fn unit_ckpt(id: u64, lineage: usize, round: u32) -> Checkpoint {
    Checkpoint {
        id: CheckpointId(id),
        lineage,
        round,
        covered_segments: round,
        size_bytes: 1,
        params: None,
    }
}

/// Unit-size byte budgets replay slot mode event for event under random
/// store/invalidate interleavings, for both an evicting and a rejecting
/// policy.
#[test]
fn prop_unit_size_byte_budget_replays_slot_mode() {
    for (seed, evicting) in [(0x51u64, true), (0x52, false)] {
        forall_prefixes(
            seed,
            40,
            |rng, size| {
                let n = 1 + (40.0 * size) as usize;
                (0..n)
                    .map(|i| {
                        (i as u64, rng.range(0, 4), rng.range(1, 9) as u32, rng.chance(0.25))
                    })
                    .collect::<Vec<_>>()
            },
            move || {
                let mk = move || -> Box<dyn cause::replacement::ReplacementPolicy> {
                    if evicting {
                        Box::new(FiboR::new())
                    } else {
                        Box::new(NoReplace)
                    }
                };
                (ModelStore::new(4, mk()), ModelStore::with_byte_budget(4, mk()))
            },
            |(slot, byte), (id, lineage, round, invalidate)| {
                if *invalidate {
                    let a = slot.invalidate(|c| c.lineage == *lineage);
                    let b = byte.invalidate(|c| c.lineage == *lineage);
                    assert_eq!(a, b, "invalidation count diverged");
                } else {
                    let a = slot.store(unit_ckpt(*id, *lineage, *round));
                    let b = byte.store(unit_ckpt(*id, *lineage, *round));
                    assert_eq!(a, b, "store event diverged");
                    assert!(
                        !matches!(b, StoreEvent::Evicted { .. }),
                        "uniform sizes must never need multi-victim receipts"
                    );
                }
            },
            |(slot, byte)| {
                if slot.stats() != byte.stats() {
                    return Err(format!(
                        "stats diverged: {:?} vs {:?}",
                        slot.stats(),
                        byte.stats()
                    ));
                }
                if slot.occupied() != byte.occupied() {
                    return Err("occupancy diverged".into());
                }
                if slot.stored_bytes() != byte.stored_bytes() {
                    return Err("byte counters diverged".into());
                }
                for l in 0..4 {
                    for cover in 0..10 {
                        if slot.best_checkpoint(l, cover).map(|c| c.id)
                            != byte.best_checkpoint(l, cover).map(|c| c.id)
                        {
                            return Err(format!("best_checkpoint({l},{cover}) diverged"));
                        }
                    }
                    if slot.latest(l).map(|c| c.id) != byte.latest(l).map(|c| c.id) {
                        return Err(format!("latest({l}) diverged"));
                    }
                }
                Ok(())
            },
        );
    }
}

fn workload(cfg: &ExperimentConfig) -> (EdgePopulation, RequestTrace) {
    let pop = EdgePopulation::generate(PopulationConfig {
        spec: cfg.dataset.scaled(12_000),
        users: cfg.users,
        rounds: cfg.rounds,
        size_sigma: 0.8,
        label_alpha: 0.5,
        arrival_prob: 0.8,
        seed: cfg.seed,
    });
    let trace = RequestTrace::generate(
        &pop,
        &TraceConfig {
            unlearn_prob: cfg.unlearn_prob,
            block_incl_prob: 0.9,
            age_decay: 0.6,
            frac_range: (0.1, 0.5),
            seed: cfg.seed ^ 0x7ace,
        },
    );
    (pop, trace)
}

/// Full receipt comparison between two finished engines.
fn assert_receipts_identical(a: &Engine, b: &Engine, label: &str) {
    let (ma, mb) = (&a.metrics, &b.metrics);
    assert_eq!(ma.rsn_by_round, mb.rsn_by_round, "{label}: rsn_by_round");
    assert_eq!(ma.requests_by_round, mb.requests_by_round, "{label}: requests");
    assert_eq!(ma.warm_retrains, mb.warm_retrains, "{label}: warm retrains");
    assert_eq!(ma.scratch_retrains, mb.scratch_retrains, "{label}: scratch retrains");
    assert_eq!(ma.lineages_retrained, mb.lineages_retrained, "{label}: lineages");
    assert_eq!(ma.prunes, mb.prunes, "{label}: prune ops");
    assert_eq!(ma.ckpts_stored, mb.ckpts_stored, "{label}: stored");
    assert_eq!(ma.ckpts_replaced, mb.ckpts_replaced, "{label}: replaced");
    assert_eq!(ma.ckpts_rejected, mb.ckpts_rejected, "{label}: rejected");
    assert_eq!(ma.ckpts_invalidated, mb.ckpts_invalidated, "{label}: invalidated");
    assert_eq!(ma.energy_joules, mb.energy_joules, "{label}: energy");
    assert_eq!(a.store().stats(), b.store().stats(), "{label}: store stats");
    assert_eq!(a.store().occupied(), b.store().occupied(), "{label}: occupancy");
    assert_eq!(
        a.store().stored_bytes(),
        b.store().stored_bytes(),
        "{label}: stored bytes"
    );
    for l in 0..a.cfg.shards {
        assert_eq!(
            a.store().latest(l).map(|c| (c.id, c.covered_segments)),
            b.store().latest(l).map(|c| (c.id, c.covered_segments)),
            "{label}: latest({l})"
        );
    }
}

/// keep=1.0 ⇒ every cost-backend checkpoint has the same (dense) size, so
/// a byte budget of N x that size must replay the N-slot store exactly —
/// across a whole engine lifecycle, for CAUSE (FiboR) and SISA
/// (no-replacement).
#[test]
fn byte_meter_equals_slot_meter_at_keep_one() {
    for variant in [SystemVariant::Cause, SystemVariant::Sisa] {
        let mut base = ExperimentConfig {
            users: 30,
            rounds: 12,
            shards: 4,
            unlearn_prob: 0.6,
            prune_keep: 1.0, // keep everything: uniform checkpoint sizes
            seed: 23,
            ..Default::default()
        };
        let unit = CostTrainer::new(base.model, variant.schedule(&base)).checkpoint_bytes();
        base.memory_bytes = 6 * unit; // 6 slots' worth, exactly divisible
        let (pop, trace) = workload(&base);

        let mut slot_cfg = base.clone();
        slot_cfg.store_meter = StoreMeter::Slots;
        let mut byte_cfg = base.clone();
        byte_cfg.store_meter = StoreMeter::Bytes;

        let mut slot_engine = variant.build_cost(&slot_cfg).unwrap();
        let mut byte_engine = variant.build_cost(&byte_cfg).unwrap();
        assert_eq!(slot_engine.store().capacity(), 6);
        assert_eq!(byte_engine.store().mode(), CapacityMode::Bytes(6 * unit));
        slot_engine.run_trace(&pop, &trace).unwrap();
        byte_engine.run_trace(&pop, &trace).unwrap();
        assert_receipts_identical(&slot_engine, &byte_engine, variant.display());
        // The workload actually exercised the capacity machinery.
        let stats = slot_engine.store().stats();
        assert!(
            stats.replaced > 0 || stats.rejected > 0,
            "{}: store never hit capacity",
            variant.display()
        );
    }
}

fn host_engine(meter: StoreMeter, budget: u64, cfg: &ExperimentConfig) -> Engine {
    let mut cfg = cfg.clone();
    cfg.store_meter = meter;
    cfg.memory_bytes = budget;
    let trainer = HostTrainer::new(
        HostTrainerConfig {
            shapes: vec![vec![48, 48], vec![48]],
            seed: 11,
            update_frac: 0.2,
        },
        cfg.shards,
        SystemVariant::Cause.schedule(&cfg),
    );
    SystemVariant::Cause
        .build_with_trainer(&cfg, Box::new(trainer), EvalPolicy::Never)
        .unwrap()
}

/// The tentpole claim, as a tier-1 test: at keep=0.3 with real tensors the
/// byte-metered store keeps ≥2x the checkpoints of the slot-metered store
/// in the same C_m, and converts them into strictly less replay (RSN).
#[test]
fn byte_meter_packs_2x_checkpoints_and_cuts_rsn_at_keep_03() {
    let base = ExperimentConfig {
        users: 30,
        rounds: 16,
        shards: 4,
        unlearn_prob: 0.6,
        prune_keep: 0.3,
        seed: 41,
        ..Default::default()
    };
    let shapes = vec![vec![48, 48], vec![48]];
    // C_m = 6 dense-slot checkpoints; the slot meter provisions for the
    // codec's dense fallback, the byte meter packs true encoded sizes.
    let budget = 6 * dense_upper_bound(&shapes);
    let (pop, trace) = workload(&base);

    let mut slot_engine = host_engine(StoreMeter::Slots, budget, &base);
    let mut byte_engine = host_engine(StoreMeter::Bytes, budget, &base);
    assert_eq!(slot_engine.store().capacity(), 6);
    slot_engine.run_trace(&pop, &trace).unwrap();
    byte_engine.run_trace(&pop, &trace).unwrap();

    // Same requests served either way; the store is the only difference.
    assert_eq!(
        slot_engine.metrics.total_requests(),
        byte_engine.metrics.total_requests()
    );
    assert!(slot_engine.metrics.total_requests() > 0, "trace produced no requests");

    let (slot_occ, byte_occ) = (slot_engine.store().occupied(), byte_engine.store().occupied());
    assert!(
        byte_occ >= 2 * slot_occ,
        "byte meter should pack >=2x checkpoints: {byte_occ} vs {slot_occ}"
    );
    assert!(
        byte_engine.store().stored_bytes() <= budget,
        "byte meter overran C_m"
    );
    let (slot_rsn, byte_rsn) =
        (slot_engine.metrics.total_rsn(), byte_engine.metrics.total_rsn());
    assert!(
        byte_rsn < slot_rsn,
        "more resident checkpoints must cut replay: byte {byte_rsn} vs slot {slot_rsn}"
    );
    // Encoded checkpoints really are small: average stored size well under
    // the dense slot size.
    let avg = byte_engine.store().stored_bytes() / byte_occ.max(1) as u64;
    assert!(
        (avg as f64) < 0.5 * dense_upper_bound(&shapes) as f64,
        "average encoded checkpoint {avg} not < half a dense slot"
    );
}
