//! Integration: full system runs at paper scale on the accounting backend —
//! the headline orderings and engine invariants across all eight presets.

use cause::config::ExperimentConfig;
use cause::coordinator::system::SystemVariant;
use cause::data::trace::{RequestTrace, TraceConfig};
use cause::experiments::common;

const ALL: [SystemVariant; 8] = [
    SystemVariant::Cause,
    SystemVariant::CauseNoSc,
    SystemVariant::CauseU,
    SystemVariant::CauseC,
    SystemVariant::Sisa,
    SystemVariant::Arcane,
    SystemVariant::Omp70,
    SystemVariant::Omp95,
];

fn paper_cfg() -> ExperimentConfig {
    ExperimentConfig::default() // 100 users, T=10, S=4, 2 GB, rho_u=0.1
}

#[test]
fn headline_ordering_cause_wins_rsn_and_energy() {
    let cfg = paper_cfg();
    let cause = common::run_cost(SystemVariant::Cause, &cfg).unwrap();
    for other in [SystemVariant::Sisa, SystemVariant::Arcane, SystemVariant::Omp70] {
        let m = common::run_cost(other, &cfg).unwrap();
        assert!(
            cause.total_rsn() < m.total_rsn(),
            "CAUSE {} !< {} {}",
            cause.total_rsn(),
            other.display(),
            m.total_rsn()
        );
        assert!(cause.energy_joules < m.energy_joules, "{}", other.display());
    }
}

#[test]
fn every_system_serves_every_request() {
    let cfg = paper_cfg();
    let pop = common::population(&cfg);
    let trace = RequestTrace::generate(
        &pop,
        &TraceConfig::paper_default(cfg.seed ^ 0x7ace).with_prob(cfg.unlearn_prob),
    );
    let expected = trace.total_requests() as u64;
    for v in ALL {
        let m = common::run_cost(v, &cfg).unwrap();
        assert_eq!(m.total_requests(), expected, "{}", v.display());
        assert!(m.total_rsn() > 0, "{} did no retraining", v.display());
        assert_eq!(m.rsn_by_round.len(), cfg.rounds as usize);
    }
}

#[test]
fn store_never_exceeds_capacity_and_accounting_balances() {
    let cfg = paper_cfg().with_memory_gb(0.5);
    for v in ALL {
        let pop = common::population(&cfg);
        let trace = common::trace(&cfg, &pop);
        let mut engine = v.build_cost(&cfg).unwrap();
        engine.run_trace(&pop, &trace).unwrap();
        let store = engine.store();
        assert!(store.occupied() <= store.capacity(), "{}", v.display());
        let m = &engine.metrics;
        // Stored = placed into a slot; every replacement implies a store.
        assert!(m.ckpts_replaced <= m.ckpts_stored, "{}", v.display());
        // No-replacement systems never replace.
        if matches!(
            v,
            SystemVariant::Sisa | SystemVariant::Arcane | SystemVariant::Omp70 | SystemVariant::Omp95
        ) {
            assert_eq!(m.ckpts_replaced, 0, "{}", v.display());
        }
    }
}

#[test]
fn deterministic_across_runs() {
    let cfg = paper_cfg();
    for v in [SystemVariant::Cause, SystemVariant::Sisa] {
        let a = common::run_cost(v, &cfg).unwrap();
        let b = common::run_cost(v, &cfg).unwrap();
        assert_eq!(a.total_rsn(), b.total_rsn(), "{}", v.display());
        assert_eq!(a.rsn_by_round, b.rsn_by_round, "{}", v.display());
        assert_eq!(a.energy_joules, b.energy_joules, "{}", v.display());
    }
}

#[test]
fn unlearned_samples_leave_the_lineages() {
    let cfg = paper_cfg();
    let pop = common::population(&cfg);
    let trace = common::trace(&cfg, &pop);
    let mut engine = SystemVariant::Cause.build_cost(&cfg).unwrap();
    engine.run_trace(&pop, &trace).unwrap();
    let removed = trace.total_unlearned_samples();
    let held = engine.lineages().total_samples();
    assert_eq!(
        held + removed,
        pop.total_samples(),
        "sample conservation: held {held} + removed {removed} != total {}",
        pop.total_samples()
    );
}

#[test]
fn memory_pressure_monotonically_hurts_no_replacement_systems() {
    // Fig. 14a's mechanism: SISA's RSN grows as memory shrinks.
    let rsn = |gb: f64| {
        common::run_cost(SystemVariant::Sisa, &paper_cfg().with_memory_gb(gb))
            .unwrap()
            .total_rsn()
    };
    let large = rsn(4.0);
    let small = rsn(0.5);
    assert!(
        small > large,
        "SISA at 0.5GB ({small}) should exceed 4GB ({large})"
    );
}

#[test]
fn unlearn_probability_scales_rsn_for_all_systems() {
    for v in [SystemVariant::Cause, SystemVariant::Sisa] {
        let lo = common::run_cost(v, &paper_cfg().with_unlearn_prob(0.1)).unwrap();
        let hi = common::run_cost(v, &paper_cfg().with_unlearn_prob(0.5)).unwrap();
        assert!(
            hi.total_rsn() > lo.total_rsn() * 2,
            "{}: {} vs {}",
            v.display(),
            lo.total_rsn(),
            hi.total_rsn()
        );
    }
}

#[test]
fn pruned_systems_fit_more_checkpoints() {
    let cfg = paper_cfg();
    let cause = SystemVariant::Cause.build_cost(&cfg).unwrap();
    let omp95 = SystemVariant::Omp95.build_cost(&cfg).unwrap();
    let sisa = SystemVariant::Sisa.build_cost(&cfg).unwrap();
    assert!(cause.store().capacity() > sisa.store().capacity() * 2);
    assert!(omp95.store().capacity() > cause.store().capacity());
}
