//! Chaos soak harness: seeded fault schedules over corpus scenarios.
//!
//! CI's dedicated soak job (`cargo run --release --bin soak`) runs the
//! wide multi-seed sweep; these tests keep a small but representative
//! matrix inside tier-1 so a broken invariant checker or a durability
//! regression fails `cargo test` directly:
//!
//! * every fault class (kill+failover, transport burst, fsync failure,
//!   battery collapse, crash-restart) lands at least once per run;
//! * both shipping paths soak — the in-process replica store and the
//!   file-backed spool whose frames survive process death;
//! * a clean run reports zero invariant violations, a fully drained
//!   ledger, and per-shard replicas bounded by the source's live WAL;
//! * the whole harness is deterministic: same (scenario, plan, cfg)
//!   twice gives byte-identical reports.

use cause::load::chaos::{run_chaos, ChaosCfg, ChaosPlan, ChaosReport, FaultClass};
use cause::load::corpus;
use cause::load::Scenario;

/// Small soak shape shared by the tests: enough ticks for one fault of
/// every class (plans schedule max(1, ticks/32) per class) with frequent
/// invariant checkpoints.
fn small_cfg(seed: u64, spool: bool) -> ChaosCfg {
    ChaosCfg {
        ticks: 28,
        check_every: 7,
        seed,
        spool,
        ..ChaosCfg::default()
    }
}

fn find(name: &str) -> Box<dyn Scenario> {
    corpus()
        .into_iter()
        .find(|s| s.name() == name)
        .unwrap_or_else(|| panic!("corpus scenario {name} missing"))
}

/// Run one soak and fail the test with the full violation list if the
/// report is not clean.
fn soak(name: &str, seed: u64, spool: bool) -> ChaosReport {
    let scenario = find(name);
    let plan = ChaosPlan::seeded(seed, 28, &FaultClass::ALL);
    let report = run_chaos(scenario.as_ref(), &plan, &small_cfg(seed, spool))
        .unwrap_or_else(|e| panic!("{name} seed {seed:#x}: harness error: {e:#}"));
    assert!(
        report.ok(),
        "{name} seed {seed:#x} (spool={spool}) violated invariants:\n  {}",
        report.violations.join("\n  ")
    );
    report
}

fn classes_applied(report: &ChaosReport) -> Vec<&'static str> {
    report.faults.iter().map(|f| f.class).collect()
}

#[test]
fn chaos_soak_battery_scenario_survives_all_fault_classes() {
    // satellite_windows: harvest-limited eclipse orbit — battery
    // collapse actually parks work, crash-restart must replay the
    // battery anchors.
    let report = soak("satellite_windows", 0xc4a0_0001, false);
    let classes = classes_applied(&report);
    for class in FaultClass::ALL {
        assert!(
            classes.contains(&class.name()),
            "plan skipped {} (applied: {classes:?})",
            class.name()
        );
    }
    assert!(report.failovers >= 1, "kill/fsync faults must fail over");
    assert!(report.restarts >= 1, "crash_restart must rebuild the fleet");
    assert!(report.barriers > 0 && report.submitted > 0);
    assert_eq!(report.served, report.submitted, "ledger must balance");
    // The final barrier ran against a compacted source: every shard's
    // peer replica stays within 2x the live WAL.
    assert!(!report.replica_bytes.is_empty());
    for (k, (&r, &l)) in
        report.replica_bytes.iter().zip(&report.live_bytes).enumerate()
    {
        assert!(r <= 2 * l.max(1), "shard {k}: replica {r} bytes vs live {l}");
    }
}

#[test]
fn chaos_soak_fleet_churn_scenario_stays_clean() {
    // iot_fleet_churn re-routes new users onto a shrunken active set
    // every cycle — chaos faults must compose with routing churn.
    let report = soak("iot_fleet_churn", 0xc4a0_0002, false);
    assert_eq!(report.served, report.submitted);
    assert!(report.restarts >= 1);
}

#[test]
fn chaos_soak_over_file_backed_spool() {
    // Same invariants with shipping over the on-disk FileSpool: failover
    // and crash recovery read replicas back through a freshly reopened
    // spool, exactly as a separate process would.
    let report = soak("gdpr_storm", 0xc4a0_0003, true);
    assert!(report.spool);
    assert!(report.failovers >= 1);
    assert_eq!(report.served, report.submitted);
}

#[test]
fn chaos_soak_is_deterministic() {
    let scenario = find("gdpr_storm");
    let plan = ChaosPlan::seeded(0xc4a0_0004, 28, &FaultClass::ALL);
    let cfg = small_cfg(0xc4a0_0004, false);
    let a = run_chaos(scenario.as_ref(), &plan, &cfg).expect("first run");
    let b = run_chaos(scenario.as_ref(), &plan, &cfg).expect("second run");
    assert!(a.ok(), "violations:\n  {}", a.violations.join("\n  "));
    assert_eq!(
        a.to_json().to_pretty(),
        b.to_json().to_pretty(),
        "same (scenario, plan, cfg) must replay byte-identically"
    );
}
