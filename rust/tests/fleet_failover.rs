//! Shard-death failover under fault injection.
//!
//! The fleet's durability story: every shard journals locally and ships
//! its sealed WAL frames to a peer replica. These tests prove the three
//! claims that story rests on:
//!
//! * **Zero lost obligations** — kill any worker after its shipped
//!   watermark catches the log head, fail over onto the replica, drive
//!   the rest of the workload, and the fleet is receipt-identical to one
//!   that never died (shard receipts, merged latency histogram, and
//!   aggregate metrics), with the routing epoch bumped exactly once so
//!   the failover is auditable.
//! * **Convergence under transport faults** — with drops, duplicates,
//!   and stale re-deliveries injected into the shipping transport, the
//!   retry/backoff loop still converges every replica to a byte-identical
//!   copy of its shard's WAL, and a failover after convergence still
//!   loses nothing.
//! * **Compaction kill-points** — crash a shard's filesystem at byte
//!   budgets spanning every write step of a compaction (snapshot, fresh
//!   log, manifest commit, old-generation removal); rebuilding the fleet
//!   from the surviving images always lands on the merged pre-crash
//!   receipt, whichever shard died.
//! * **Fsync poisoning** — an injected fsync failure on one shard's
//!   journal poisons every fallible front-end op (nothing is acked over
//!   a torn journal) until failover replaces the shard.
//! * **Backoff saturation** — a permanently-dead transport exhausts the
//!   shipper's retry budget cleanly: terminal `failed`, sticky
//!   `last_error`, and full retry diagnostics in the merged receipt,
//!   with the journal itself unharmed.
//! * **File-backed spool failover** — shipping over the on-disk
//!   [`FileSpool`] leaves enough on the peer's filesystem that a
//!   failover recovering from a *reopened* spool (what a fresh process
//!   would find after the peer died) still loses nothing.

use std::sync::Arc;

use cause::config::ExperimentConfig;
use cause::coordinator::system::SystemVariant;
use cause::data::dataset::{EdgePopulation, PopulationConfig};
use cause::data::trace::{RequestTrace, TraceConfig};
use cause::fleet::FleetService;
use cause::memory::StoreMeter;
use cause::persist::frame::HEADER_LEN;
use cause::persist::ship::materialize_replica;
use cause::persist::{
    Durability, DurabilityMode, FileSpool, FsyncPolicy, MemFs, Replica, ReplicaSource,
    ReplicaStore, ShipTransport, Shipment,
};
use cause::testkit::{FailpointFs, FailpointTransport};
use cause::util::Json;

const WAL: &str = "wal-0.log";
const MANIFEST: &str = "MANIFEST.json";

/// FiboR + byte-budget workload with enough cross-shard traffic that
/// both workers of a 2-shard fleet serve real requests.
fn workload(seed: u64) -> (ExperimentConfig, EdgePopulation, RequestTrace) {
    let mut cfg = ExperimentConfig {
        users: 20,
        rounds: 6,
        shards: 4,
        unlearn_prob: 0.7,
        seed,
        ..Default::default()
    };
    cfg.memory_bytes = 64 * 1024;
    cfg.store_meter = StoreMeter::Bytes;
    let pop = EdgePopulation::generate(PopulationConfig {
        spec: cfg.dataset.scaled(8_000),
        users: cfg.users,
        rounds: cfg.rounds,
        size_sigma: 0.8,
        label_alpha: 0.5,
        arrival_prob: 0.8,
        seed: cfg.seed,
    });
    let trace = RequestTrace::generate(
        &pop,
        &TraceConfig {
            unlearn_prob: cfg.unlearn_prob,
            block_incl_prob: 0.8,
            age_decay: 0.5,
            frac_range: (0.1, 0.5),
            seed: cfg.seed ^ 0xf1ee7,
        },
    );
    (cfg, pop, trace)
}

/// One scheduled round: ingest, clock skew, submits, batched drain.
fn step_round(f: &mut FleetService, t: u32, pop: &EdgePopulation, trace: &RequestTrace) {
    f.ingest_round(pop).unwrap();
    f.advance(u64::from(t) % 3);
    for req in trace.at(t) {
        f.submit(req.clone());
    }
    f.drain_batched().unwrap();
}

/// Kill each worker in turn after its shipped watermark catches the log
/// head; the failed-over fleet must be receipt-identical to one that
/// never died — zero acknowledged obligations lost.
#[test]
fn killing_any_worker_loses_zero_acked_obligations() {
    for k in 0..2usize {
        let (mut cfg, pop, trace) = workload(33);
        cfg.fleet_workers = 2;

        let build = || {
            let mut f = SystemVariant::Cause.build_fleet(&cfg).unwrap();
            f.attach_durability(vec![
                Durability::mem(DurabilityMode::Log, MemFs::new(), 0),
                Durability::mem(DurabilityMode::Log, MemFs::new(), 0),
            ])
            .unwrap();
            f.enable_log_shipping().unwrap();
            f
        };
        let mut a = build(); // shard k dies mid-run
        let mut b = build(); // never killed

        for t in 1..=3u32 {
            step_round(&mut a, t, &pop, &trace);
            step_round(&mut b, t, &pop, &trace);
        }
        // Seal + ship everything acknowledged so far; the clean
        // in-process transport drains every shipper in one flush.
        a.sync_journals().unwrap();
        b.sync_journals().unwrap();
        for (r, log_seq) in a.shipping_states().unwrap() {
            let r = r.expect("shipping enabled");
            assert_eq!(r.pending, 0, "sealed frames must all be shipped");
            assert_eq!(r.shipped_seq, log_seq, "watermark must reach the log head");
            assert!(r.failed.is_none());
        }

        a.kill_worker(k).unwrap();
        // Dead shard: fallible fleet ops refuse (a partial answer over a
        // sharded obligation set would lie)...
        assert!(a.drain_batched().is_err());
        assert!(a.state_receipt().is_err());
        // ...while fire-and-forget traffic parks in arrival order. The
        // reference fleet sees the identical schedule, live.
        for req in trace.at(4) {
            a.submit(req.clone());
            b.submit(req.clone());
        }
        a.advance(2);
        b.advance(2);

        let report = a.failover(k).unwrap();
        assert!(
            report.events_replayed > 0 || report.snapshot_loaded,
            "failover must recover the shipped log: {report:?}"
        );

        // Identical schedules from here on (round 4's submits already
        // happened on both sides, in the same order).
        for f in [&mut a, &mut b] {
            f.ingest_round(&pop).unwrap();
            f.drain_batched().unwrap();
        }
        for t in 5..=cfg.rounds {
            step_round(&mut a, t, &pop, &trace);
            step_round(&mut b, t, &pop, &trace);
        }
        let served_a = a.flush_batched().unwrap();
        let served_b = b.flush_batched().unwrap();
        assert_eq!(served_a, served_b, "shard {k}: flush served counts diverged");
        a.sync_journals().unwrap();
        b.sync_journals().unwrap();

        let ra = a.state_receipt().unwrap();
        let rb = b.state_receipt().unwrap();
        assert_eq!(
            ra.at(&["shards"]),
            rb.at(&["shards"]),
            "shard {k}: killed-and-failed-over fleet diverged from the never-killed one"
        );
        assert_eq!(ra.at(&["latency_hist"]), rb.at(&["latency_hist"]));
        assert_eq!(
            a.metrics().unwrap().to_json().to_string(),
            b.metrics().unwrap().to_json().to_string(),
            "shard {k}: aggregate metrics diverged"
        );
        // The failover is receipt-auditable: exactly one epoch bump.
        assert_eq!(a.epoch(), b.epoch() + 1);
    }
}

/// Log shipping converges to a byte-identical peer copy of every
/// shard's WAL even when the transport drops, duplicates, and reorders
/// shipments — and a failover after convergence still loses nothing.
#[test]
fn shipping_converges_and_fails_over_under_transport_faults() {
    let (mut cfg, pop, trace) = workload(57);
    cfg.fleet_workers = 2;

    let fs0 = MemFs::new();
    let fs1 = MemFs::new();
    let mut a = SystemVariant::Cause.build_fleet(&cfg).unwrap();
    a.attach_durability(vec![
        Durability::mem(DurabilityMode::Log, fs0.clone(), 0),
        Durability::mem(DurabilityMode::Log, fs1.clone(), 0),
    ])
    .unwrap();
    let store = a
        .enable_log_shipping_with(|k, store| {
            // Heavy fault rates, deterministic per shard.
            Box::new(FailpointTransport::new(
                Box::new(store),
                0xF417_0000 ^ k as u64,
                0.35,
                0.3,
                0.3,
            ))
        })
        .unwrap();

    // Fault-free reference: the transport never touches service state,
    // so the faulty fleet must stay receipt-identical to this one.
    let mut b = SystemVariant::Cause.build_fleet(&cfg).unwrap();
    b.attach_durability(vec![
        Durability::mem(DurabilityMode::Log, MemFs::new(), 0),
        Durability::mem(DurabilityMode::Log, MemFs::new(), 0),
    ])
    .unwrap();

    for t in 1..=cfg.rounds {
        step_round(&mut a, t, &pop, &trace);
        step_round(&mut b, t, &pop, &trace);
    }

    // Pump seals until every shipper drains through the faulty pipe
    // (each seal is one flush opportunity; backoff skips some).
    let mut spins = 0;
    loop {
        a.sync_journals().unwrap();
        let states = a.shipping_states().unwrap();
        for (r, _) in &states {
            let r = r.as_ref().expect("shipping enabled");
            assert!(r.failed.is_none(), "retry budget must absorb the faults: {r:?}");
        }
        if states.iter().all(|(r, log_seq)| {
            let r = r.as_ref().unwrap();
            r.pending == 0 && r.shipped_seq == *log_seq
        }) {
            break;
        }
        spins += 1;
        assert!(spins < 10_000, "shipping must converge under transport faults");
    }

    // Each replica re-frames to the exact bytes of its shard's WAL: same
    // payloads, same checksum chain.
    for (k, fs) in [&fs0, &fs1].into_iter().enumerate() {
        let replica = store.replica(k).expect("replica exists");
        let mat = materialize_replica(&replica);
        assert_eq!(mat.file(WAL), fs.file(WAL), "shard {k}: replica WAL diverged");
    }

    // Failover after convergence: still zero loss.
    a.kill_worker(1).unwrap();
    a.failover(1).unwrap();
    let served_a = a.flush_batched().unwrap();
    let served_b = b.flush_batched().unwrap();
    assert_eq!(served_a, served_b);
    let ra = a.state_receipt().unwrap();
    let rb = b.state_receipt().unwrap();
    assert_eq!(ra.at(&["shards"]), rb.at(&["shards"]));
    assert_eq!(a.epoch(), b.epoch() + 1);
}

/// Fleet compaction kill-points: crash a shard's filesystem at byte
/// budgets spanning every write step of the compaction — nothing lands,
/// a torn/orphan snapshot, snapshot + fresh log but no manifest, the
/// manifest commit itself, and the blocked old-generation removal.
/// Rebuilding the fleet from the surviving images must always land on
/// the merged pre-crash receipt: compaction is receipt-invisible no
/// matter where it dies, on either shard.
#[test]
fn fleet_compaction_killpoints_preserve_merged_receipts() {
    let (mut cfg, pop, trace) = workload(71);
    cfg.fleet_workers = 2;

    // Drive once, journaling to plain memory; every kill-point below
    // rebuilds from forks of these images.
    let fs = [MemFs::new(), MemFs::new()];
    let mut fleet = SystemVariant::Cause.build_fleet(&cfg).unwrap();
    fleet
        .attach_durability(vec![
            Durability::mem(DurabilityMode::Log, fs[0].clone(), 0),
            Durability::mem(DurabilityMode::Log, fs[1].clone(), 0),
        ])
        .unwrap();
    for t in 1..=cfg.rounds {
        step_round(&mut fleet, t, &pop, &trace);
    }
    let receipt_before = fleet.state_receipt().unwrap().to_string();
    drop(fleet);

    // Recover a fleet from per-shard images and compact with shard k's
    // filesystem armed to die after `budget` written bytes; returns the
    // surviving images and the unspent budget.
    let run = |k: usize, budget: u64| -> ([MemFs; 2], u64) {
        let imgs = [fs[0].fork(), fs[1].fork()];
        let fp = FailpointFs::new(imgs[k].clone());
        let mut f = SystemVariant::Cause.build_fleet(&cfg).unwrap();
        let ds = (0..2)
            .map(|j| {
                if j == k {
                    Durability {
                        mode: DurabilityMode::Log,
                        fs: Box::new(fp.clone()),
                        compact_every: 0,
                        fsync: FsyncPolicy::Never,
                    }
                } else {
                    Durability::mem(DurabilityMode::Log, imgs[j].clone(), 0)
                }
            })
            .collect();
        f.attach_durability(ds).unwrap();
        fp.set_budget(Some(budget));
        // Past the budget, writes vanish silently (the power is out);
        // whether the call "succeeds" is irrelevant — the fleet is
        // discarded either way, only the images survive.
        let _ = f.compact_now();
        drop(f);
        let left = fp.remaining().expect("budget still armed");
        fp.set_budget(None);
        (imgs, left)
    };
    let recover = |imgs: [MemFs; 2]| -> FleetService {
        let [i0, i1] = imgs;
        let mut f = SystemVariant::Cause.build_fleet(&cfg).unwrap();
        f.attach_durability(vec![
            Durability::mem(DurabilityMode::Log, i0, 0),
            Durability::mem(DurabilityMode::Log, i1, 0),
        ])
        .unwrap();
        f
    };

    for k in 0..2usize {
        // Probe with an ample budget: the compaction commits, and the
        // consumed bytes expose the write-step boundaries.
        const AMPLE: u64 = 1 << 40;
        let (committed, left) = run(k, AMPLE);
        let consumed = AMPLE - left;
        let sizes = committed[k].sizes();
        let snap_len = sizes
            .iter()
            .find(|(n, _)| n.starts_with("snapshot-"))
            .map(|(_, l)| *l)
            .expect("probe compaction must write a snapshot");
        let manifest_len = sizes.iter().find(|(n, _)| n == MANIFEST).unwrap().1;
        // Write-step model: snapshot, fresh-log header, manifest commit,
        // one old-log removal (1 budget unit). Keeps the sampling honest
        // — if compaction grows a step, this fails loudly.
        let log_commit = snap_len + HEADER_LEN as u64;
        let man_commit = log_commit + manifest_len;
        assert_eq!(consumed, man_commit + 1, "shard {k}: compaction write-step model");
        let f = recover(committed);
        assert_eq!(
            f.state_receipt().unwrap().to_string(),
            receipt_before,
            "shard {k}: committed compaction must be receipt-invisible"
        );
        drop(f);

        // Every distinct step outcome, plus the exact boundaries.
        let mut budgets = vec![
            0,
            1,
            snap_len / 2,
            snap_len - 1,
            snap_len,
            snap_len + 1,
            log_commit - 1,
            log_commit,
            log_commit + 1,
            log_commit + manifest_len / 2,
            man_commit - 1,
            man_commit,
            man_commit + 1,
        ];
        budgets.sort_unstable();
        budgets.dedup();
        for budget in budgets {
            let (imgs, _) = run(k, budget);
            let f = recover(imgs);
            assert_eq!(
                f.state_receipt().unwrap().to_string(),
                receipt_before,
                "shard {k}: compaction killed at byte budget {budget} must recover \
                 the merged pre-crash receipt"
            );
        }
    }
}

/// An injected fsync failure on one shard's journal poisons every
/// fallible front-end operation — the fleet refuses to ack anything over
/// a torn journal — until failover replaces the shard from its shipped
/// replica.
#[test]
fn fsync_failure_poisons_fleet_ops_until_failover() {
    let (mut cfg, pop, trace) = workload(91);
    cfg.fleet_workers = 2;
    let fps: Vec<FailpointFs> = (0..2).map(|_| FailpointFs::new(MemFs::new())).collect();
    let mut f = SystemVariant::Cause.build_fleet(&cfg).unwrap();
    f.attach_durability(
        fps.iter()
            .map(|fp| Durability {
                mode: DurabilityMode::Log,
                fs: Box::new(fp.clone()),
                compact_every: 0,
                fsync: FsyncPolicy::GroupCommit,
            })
            .collect(),
    )
    .unwrap();
    f.enable_log_shipping().unwrap();

    for t in 1..=3u32 {
        step_round(&mut f, t, &pop, &trace);
    }
    f.sync_journals().unwrap();

    // Arm one fsync failure on shard 0 and dirty every journal with a
    // zero-tick Advance (no logical state change): the next seal issues
    // the barrier that fails.
    fps[0].fail_next_syncs(1);
    f.advance(0);
    let err = f.sync_journals().unwrap_err().to_string();
    assert!(err.contains("injected fsync failure"), "unexpected error: {err}");

    // The poison is sticky: every fallible front-end op refuses.
    assert!(f.drain_batched().is_err());
    assert!(f.flush_batched().is_err());
    assert!(f.sync_journals().is_err());

    // Failover onto the shipped replica heals the fleet.
    f.kill_worker(0).unwrap();
    let report = f.failover(0).unwrap();
    assert!(
        report.events_replayed > 0 || report.snapshot_loaded,
        "failover must recover the shipped log: {report:?}"
    );
    f.sync_journals().unwrap();
    for t in 4..=cfg.rounds {
        step_round(&mut f, t, &pop, &trace);
    }
    f.flush_batched().unwrap();
    f.state_receipt().unwrap();
}

/// A transport that never delivers anything.
struct DeadTransport;

impl ShipTransport for DeadTransport {
    fn deliver(&mut self, _source: usize, _s: &Shipment) -> Result<u64, String> {
        Err("transport down".to_string())
    }
}

/// A permanently-dead transport exhausts the shipper's retry budget
/// cleanly: terminal `failed`, sticky `last_error`, faults == attempts —
/// and the journal itself is unharmed (drains keep working; the loss is
/// replication headroom, not durability). The merged receipt carries the
/// full retry diagnostics plus each shard's journal fsync counters.
#[test]
fn shipper_backoff_saturates_cleanly_on_dead_transport() {
    let (mut cfg, pop, trace) = workload(101);
    cfg.fleet_workers = 2;
    let mut f = SystemVariant::Cause.build_fleet(&cfg).unwrap();
    f.attach_durability(vec![
        Durability::mem(DurabilityMode::Log, MemFs::new(), 0),
        Durability::mem(DurabilityMode::Log, MemFs::new(), 0),
    ])
    .unwrap();
    f.enable_log_shipping_custom(Arc::new(ReplicaStore::new()), |_k| Box::new(DeadTransport))
        .unwrap();

    for t in 1..=3u32 {
        step_round(&mut f, t, &pop, &trace);
    }
    // Pump seals until every shipper's retry budget exhausts (backoff
    // skips spread the attempts over many flush opportunities).
    let mut gave_up = false;
    for _ in 0..10_000 {
        f.sync_journals().unwrap(); // shipping failure is not a journal failure
        let states = f.shipping_states().unwrap();
        if states.iter().all(|(r, _)| r.as_ref().unwrap().failed.is_some()) {
            gave_up = true;
            break;
        }
    }
    assert!(gave_up, "dead transport must exhaust the retry budget");
    for (r, log_seq) in f.shipping_states().unwrap() {
        let r = r.expect("shipping enabled");
        assert!(r.failed.as_ref().unwrap().contains("transport down"), "{r:?}");
        assert_eq!(r.last_error.as_deref(), Some("transport down"));
        assert_eq!(r.faults, r.attempts, "every delivery must have faulted");
        assert!(r.attempts >= 8, "terminal failure needs the full retry budget: {r:?}");
        assert_eq!(r.shipped_seq, 0, "nothing can have shipped");
        assert!(r.pending > 0);
        assert!(log_seq > 0);
    }

    // Journal unharmed: the fleet still serves and seals.
    f.ingest_round(&pop).unwrap();
    f.drain_batched().unwrap();

    // Satellite diagnostics in the merged receipt: retry counters, the
    // last transport error, and journal fsync stats per shard.
    let receipt = f.state_receipt().unwrap();
    let shipping = receipt.at(&["shipping"]).unwrap().as_arr().unwrap();
    assert_eq!(shipping.len(), 2);
    for entry in shipping {
        assert_eq!(
            entry.get("last_error").and_then(Json::as_str),
            Some("transport down")
        );
        assert!(entry.get("failed").and_then(Json::as_str).is_some());
        assert!(entry.get("attempts").and_then(Json::as_u64).unwrap() >= 8);
        assert!(entry.get("faults").and_then(Json::as_u64).unwrap() >= 8);
        let journal = entry.get("journal").expect("per-shard journal stats");
        assert!(journal.get("fsyncs").and_then(Json::as_u64).is_some());
        assert!(journal.get("log_bytes").and_then(Json::as_u64).unwrap() > 0);
        assert!(journal.get("appended").and_then(Json::as_u64).unwrap() > 0);
    }
}

/// Failover source that **reopens** the spool from its backing
/// filesystem on every read — recovery sees exactly what a fresh process
/// would find on the peer's disk after the shipping process died.
struct ReopenSpool {
    fs: MemFs,
}

impl ReplicaSource for ReopenSpool {
    fn replica(&self, source: usize) -> Option<Replica> {
        FileSpool::open(Box::new(self.fs.clone())).replica(source)
    }
}

/// Shipping over the file-backed spool leaves everything failover needs
/// on the peer's filesystem: kill a worker and recover it from a
/// *reopened* spool (fresh parse of the on-disk index + frame files,
/// never an in-memory copy) — the failed-over fleet stays
/// receipt-identical to one that never died.
#[test]
fn failover_recovers_from_file_backed_spool() {
    let (mut cfg, pop, trace) = workload(113);
    cfg.fleet_workers = 2;

    let spool_fs = MemFs::new();
    let mut a = SystemVariant::Cause.build_fleet(&cfg).unwrap();
    a.attach_durability(vec![
        Durability::mem(DurabilityMode::Log, MemFs::new(), 0),
        Durability::mem(DurabilityMode::Log, MemFs::new(), 0),
    ])
    .unwrap();
    let spool = FileSpool::open(Box::new(spool_fs.clone()));
    a.enable_log_shipping_custom(Arc::new(ReopenSpool { fs: spool_fs.clone() }), move |_k| {
        Box::new(spool.clone())
    })
    .unwrap();

    // Reference fleet that never dies (default in-process shipping).
    let mut b = SystemVariant::Cause.build_fleet(&cfg).unwrap();
    b.attach_durability(vec![
        Durability::mem(DurabilityMode::Log, MemFs::new(), 0),
        Durability::mem(DurabilityMode::Log, MemFs::new(), 0),
    ])
    .unwrap();
    b.enable_log_shipping().unwrap();

    for t in 1..=3u32 {
        step_round(&mut a, t, &pop, &trace);
        step_round(&mut b, t, &pop, &trace);
    }
    a.sync_journals().unwrap();
    b.sync_journals().unwrap();
    for (r, log_seq) in a.shipping_states().unwrap() {
        let r = r.expect("shipping enabled");
        assert_eq!(r.pending, 0);
        assert_eq!(r.shipped_seq, log_seq);
    }
    // The spool really is on disk: index plus per-source frame files.
    let names: Vec<String> = spool_fs.sizes().into_iter().map(|(n, _)| n).collect();
    assert!(names.iter().any(|n| n == "SPOOL.json"), "spool index on disk: {names:?}");
    assert!(
        names.iter().any(|n| n.starts_with("spool-1.")),
        "shard 1 frames on disk: {names:?}"
    );

    a.kill_worker(1).unwrap();
    let report = a.failover(1).unwrap();
    assert!(
        report.events_replayed > 0 || report.snapshot_loaded,
        "failover must recover from the reopened spool: {report:?}"
    );

    for t in 4..=cfg.rounds {
        step_round(&mut a, t, &pop, &trace);
        step_round(&mut b, t, &pop, &trace);
    }
    let served_a = a.flush_batched().unwrap();
    let served_b = b.flush_batched().unwrap();
    assert_eq!(served_a, served_b);
    let ra = a.state_receipt().unwrap();
    let rb = b.state_receipt().unwrap();
    assert_eq!(
        ra.at(&["shards"]),
        rb.at(&["shards"]),
        "spool-failed-over fleet diverged from the never-killed one"
    );
    assert_eq!(ra.at(&["latency_hist"]), rb.at(&["latency_hist"]));
    assert_eq!(a.epoch(), b.epoch() + 1);
}
