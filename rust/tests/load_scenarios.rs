//! Scenario determinism: the open-loop harness is a pure function of
//! its seed. For every scenario in the corpus, running the same
//! `OpenLoopCfg` twice must produce a byte-identical report — the same
//! FNV digest over the submitted request trace (arrival order, users,
//! blocks, sample counts) and the same serialized `LoadReport` JSON
//! (all the counters and the full histogram that `bench_load` writes
//! into `BENCH_load.json`). A different seed must produce a different
//! trace, or the "seeded" RNG isn't actually steering anything.

use cause::load::{corpus, run_open_loop, sweep, OpenLoopCfg};

fn light_run(seed: u64) -> OpenLoopCfg {
    OpenLoopCfg { offered_per_tick: 1.0, ticks: 10, tail_ticks: 64, seed, obs: false }
}

#[test]
fn same_seed_is_byte_identical_for_every_scenario() {
    for sc in corpus() {
        let run = light_run(0xd0_0d);
        let a = run_open_loop(sc.as_ref(), &run).expect(sc.name());
        let b = run_open_loop(sc.as_ref(), &run).expect(sc.name());
        assert_eq!(
            a.trace_digest,
            b.trace_digest,
            "{}: request trace diverged across identical runs",
            sc.name()
        );
        assert_eq!(
            a.to_json().to_string(),
            b.to_json().to_string(),
            "{}: serialized report diverged across identical runs",
            sc.name()
        );
        // The counters the bench gates on, spelled out for diagnosis.
        assert_eq!(a.submitted, b.submitted, "{}", sc.name());
        assert_eq!(a.served, b.served, "{}", sc.name());
        assert_eq!(a.unserved, b.unserved, "{}", sc.name());
        assert_eq!(a.violations, b.violations, "{}", sc.name());
        assert_eq!(a.slo_ok, b.slo_ok, "{}", sc.name());
        assert_eq!(a.p999_over_p50(), b.p999_over_p50(), "{}", sc.name());
        assert!(a.submitted > 0, "{}: run produced no arrivals", sc.name());
    }
}

#[test]
fn different_seed_changes_the_request_trace() {
    // adversarial_oldest chooses targets deterministically by design
    // (the seed only paces it), so it is exempt from this check.
    for sc in corpus().iter().filter(|s| s.name() != "adversarial_oldest") {
        let a = run_open_loop(sc.as_ref(), &light_run(1)).expect(sc.name());
        let b = run_open_loop(sc.as_ref(), &light_run(2)).expect(sc.name());
        assert_ne!(
            a.trace_digest,
            b.trace_digest,
            "{}: seed change did not change the request trace",
            sc.name()
        );
    }
}

#[test]
fn sweep_is_deterministic_and_monotone_in_its_verdicts() {
    // A two-point mini-sweep of one cheap scenario, twice: identical
    // rps_at_slo and per-rate reports, and the lowest rate must be the
    // easiest to pass (slo_ok can only degrade as the rate grows).
    let scenarios = corpus();
    let sc = &scenarios[1]; // diurnal_burst
    let base = light_run(0xbee);
    let rates = [0.5, 4.0];
    let (rps_a, reps_a) = sweep(sc.as_ref(), &rates, &base).unwrap();
    let (rps_b, reps_b) = sweep(sc.as_ref(), &rates, &base).unwrap();
    assert_eq!(rps_a, rps_b);
    assert_eq!(reps_a.len(), reps_b.len());
    for (a, b) in reps_a.iter().zip(&reps_b) {
        assert_eq!(a.to_json().to_string(), b.to_json().to_string());
    }
    assert!(
        reps_a[0].slo_ok || !reps_a[1].slo_ok,
        "higher rate passed while the lower rate failed"
    );
}
