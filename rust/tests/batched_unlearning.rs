//! Integration: batched request coalescing vs FCFS — the equivalence and
//! accounting guarantees of the batch subsystem.
//!
//! * On a seeded burst trace (≥ 8 same-round requests over ≤ 4 lineages),
//!   `Coalesce` yields *strictly* lower total RSN than FCFS while
//!   invalidating the identical set of poisoned sub-model versions.
//! * `run_trace` total RSN equals the sum of per-request outcomes
//!   (property-tested over random small configurations).
//! * Requests served before any training round are still accounted.

use std::collections::BTreeSet;

use cause::config::ExperimentConfig;
use cause::coordinator::system::SystemVariant;
use cause::data::dataset::{EdgePopulation, PopulationConfig};
use cause::data::trace::{RequestTrace, TraceConfig, UnlearnRequest};
use cause::experiments::common;
use cause::unlearning::{BatchPlan, BatchPlanner, BatchPolicy, UnlearningService};

/// The shared seeded burst: many same-round requests over ≤ `shards`
/// lineages, eviction-free store (see `experiments::common::burst_workload`
/// — the bench prints the same workload this file asserts on).
fn burst_setup() -> (ExperimentConfig, EdgePopulation, RequestTrace) {
    common::burst_workload()
}

/// The round with the most requests (the burst the batch subsystem targets).
fn burst_round(trace: &RequestTrace, rounds: u32) -> u32 {
    (1..=rounds).max_by_key(|r| trace.at(*r).len()).expect("at least one round")
}

#[test]
fn coalesce_strictly_beats_fcfs_on_burst_with_identical_invalidation() {
    let (cfg, pop, trace) = burst_setup();
    let burst = burst_round(&trace, cfg.rounds);
    let requests: Vec<UnlearnRequest> = trace.at(burst).to_vec();
    assert!(
        requests.len() >= 8,
        "seeded burst too small: {} requests (need ≥ 8 over ≤ {} lineages)",
        requests.len(),
        cfg.shards
    );

    // FCFS: one retrain pass per request, in arrival order.
    let mut fcfs = SystemVariant::Cause.build_cost(&cfg).unwrap();
    for _ in 1..=burst {
        fcfs.run_round(&pop).unwrap();
    }
    let mut fcfs_rsn = 0u64;
    let mut fcfs_invalidated: BTreeSet<(usize, u32)> = BTreeSet::new();
    for req in &requests {
        let out = fcfs.process_request(req).unwrap();
        fcfs_rsn += out.rsn;
        fcfs_invalidated.extend(out.invalidated_versions.iter().copied());
    }

    // Coalesce: the whole burst merged into one plan.
    let mut coal = SystemVariant::Cause.build_cost(&cfg).unwrap();
    for _ in 1..=burst {
        coal.run_round(&pop).unwrap();
    }
    let stale_ids: BTreeSet<_> = coal.store().iter().map(|c| c.id).collect();
    let plan = BatchPlan::collect(&mut coal, &requests);
    assert!(
        plan.coalesced_retrains() > 0,
        "burst of {} requests over ≤ {} lineages must merge retrains",
        requests.len(),
        cfg.shards
    );
    let out = coal.execute_plan(&plan).unwrap();
    coal.metrics.record_requests(requests.len() as u64, out.rsn);
    let coal_invalidated: BTreeSet<(usize, u32)> =
        out.invalidated_versions.iter().copied().collect();

    // Headline: strictly fewer samples replayed, same versions purged.
    assert!(
        out.rsn < fcfs_rsn,
        "coalesce RSN {} must be strictly below FCFS RSN {fcfs_rsn}",
        out.rsn
    );
    assert_eq!(
        coal_invalidated, fcfs_invalidated,
        "both policies must invalidate the identical poisoned versions"
    );

    // Exact-unlearning audit: no pre-batch checkpoint of a poisoned
    // version survives in the store (survivors at those coverages are the
    // freshly retrained replacements).
    for c in coal.store().iter() {
        if coal_invalidated.contains(&(c.lineage, c.covered_segments)) {
            assert!(
                !stale_ids.contains(&c.id),
                "stale poisoned checkpoint survived: lineage {} cover {}",
                c.lineage,
                c.covered_segments
            );
        }
    }

    // Both engines accounted every request.
    assert_eq!(fcfs.metrics.total_requests(), requests.len() as u64);
    assert_eq!(coal.metrics.total_requests(), requests.len() as u64);
}

#[test]
fn service_drain_batched_beats_fcfs_drain_end_to_end() {
    let (cfg, pop, trace) = burst_setup();

    let run = |policy: BatchPolicy| -> u64 {
        let engine = SystemVariant::Cause.build_cost(&cfg).unwrap();
        let mut svc =
            UnlearningService::new(engine).with_planner(BatchPlanner::new(policy, 0));
        for t in 1..=cfg.rounds {
            svc.ingest_round(&pop).unwrap();
            for req in trace.at(t) {
                svc.submit(req.clone());
            }
            svc.drain_batched().unwrap();
        }
        assert_eq!(svc.pending(), 0);
        assert_eq!(
            svc.engine().metrics.total_requests(),
            trace.total_requests() as u64
        );
        svc.engine().metrics.total_rsn()
    };

    let fcfs_rsn = run(BatchPolicy::Fcfs);
    let coal_rsn = run(BatchPolicy::Coalesce);
    assert!(
        coal_rsn < fcfs_rsn,
        "coalesced service RSN {coal_rsn} must be strictly below FCFS {fcfs_rsn}"
    );
}

#[test]
fn request_before_any_round_is_accounted_not_dropped() {
    let (cfg, pop, trace) = burst_setup();
    let req = trace.at(1).first().cloned().expect("burst trace has requests");

    let mut engine = SystemVariant::Cause.build_cost(&cfg).unwrap();
    // Served before any training round: nothing to retrain, but the
    // request must land in the round-0 metrics slot (previously both the
    // count and RSN silently vanished).
    let out = engine.process_request(&req).unwrap();
    assert_eq!(out.rsn, 0);
    assert_eq!(engine.metrics.total_requests(), 1);
    assert_eq!(engine.metrics.rsn_by_round.len(), 1);

    // Later rounds still open their own slots.
    engine.run_round(&pop).unwrap();
    assert_eq!(engine.metrics.rsn_by_round.len(), 2);
    engine.process_request(&req).unwrap();
    assert_eq!(engine.metrics.total_requests(), 2);
}

#[test]
fn prop_run_trace_rsn_equals_sum_of_request_outcomes() {
    use cause::testkit::forall;

    forall(
        0xBA7C4,
        12,
        |rng, size| {
            let users = 6 + (14.0 * size) as usize;
            let rounds = 1 + rng.range(0, 4) as u32;
            let prob = 0.2 + 0.5 * rng.f64();
            let seed = rng.next_u64() % 1_000_000;
            (users, rounds, prob, seed)
        },
        |(users, rounds, prob, seed)| {
            let cfg = ExperimentConfig {
                users: *users,
                rounds: *rounds,
                shards: 4,
                unlearn_prob: *prob,
                seed: *seed,
                ..Default::default()
            };
            let pop = EdgePopulation::generate(PopulationConfig {
                spec: cfg.dataset.scaled(6_000),
                users: cfg.users,
                rounds: cfg.rounds,
                size_sigma: 0.8,
                label_alpha: 0.5,
                arrival_prob: 0.7,
                seed: cfg.seed,
            });
            let trace = RequestTrace::generate(
                &pop,
                &TraceConfig::paper_default(cfg.seed ^ 0x7ace).with_prob(cfg.unlearn_prob),
            );

            // Twin A: the engine's own trace driver.
            let mut auto = SystemVariant::Cause.build_cost(&cfg).unwrap();
            auto.run_trace(&pop, &trace).unwrap();

            // Twin B: manual loop accumulating per-request outcomes.
            let mut manual = SystemVariant::Cause.build_cost(&cfg).unwrap();
            let mut sum = 0u64;
            let mut served = 0u64;
            for t in 1..=cfg.rounds.min(pop.rounds()) {
                manual.run_round(&pop).unwrap();
                for req in trace.at(t) {
                    sum += manual.process_request(req).unwrap().rsn;
                    served += 1;
                }
            }

            if auto.metrics.total_rsn() != sum {
                return Err(format!(
                    "run_trace RSN {} != sum of outcomes {sum}",
                    auto.metrics.total_rsn()
                ));
            }
            if auto.metrics.total_requests() != served {
                return Err(format!(
                    "run_trace requests {} != served {served}",
                    auto.metrics.total_requests()
                ));
            }
            if manual.metrics.total_rsn() != sum {
                return Err(format!(
                    "engine metrics RSN {} != sum of its own outcomes {sum}",
                    manual.metrics.total_rsn()
                ));
            }
            Ok(())
        },
    );
}
