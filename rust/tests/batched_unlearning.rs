//! Integration: batched request coalescing vs FCFS — the equivalence and
//! accounting guarantees of the batch subsystem.
//!
//! * On a seeded burst trace (≥ 8 same-round requests over ≤ 4 lineages),
//!   `Coalesce` yields *strictly* lower total RSN than FCFS while
//!   invalidating the identical set of poisoned sub-model versions.
//! * `run_trace` total RSN equals the sum of per-request outcomes
//!   (property-tested over random small configurations).
//! * Requests served before any training round are still accounted.

use std::collections::BTreeSet;

use cause::config::ExperimentConfig;
use cause::coordinator::engine::ExecMode;
use cause::coordinator::system::SystemVariant;
use cause::data::dataset::{EdgePopulation, PopulationConfig};
use cause::data::trace::{RequestTrace, TraceConfig, UnlearnRequest};
use cause::energy::EnergyModel;
use cause::experiments::common;
use cause::sim::Battery;
use cause::unlearning::{BatchPlan, BatchPlanner, BatchPolicy, UnlearningService};

/// The shared seeded burst: many same-round requests over ≤ `shards`
/// lineages, eviction-free store (see `experiments::common::burst_workload`
/// — the bench prints the same workload this file asserts on).
fn burst_setup() -> (ExperimentConfig, EdgePopulation, RequestTrace) {
    common::burst_workload()
}

/// The round with the most requests (the burst the batch subsystem targets).
fn burst_round(trace: &RequestTrace, rounds: u32) -> u32 {
    (1..=rounds).max_by_key(|r| trace.at(*r).len()).expect("at least one round")
}

#[test]
fn coalesce_strictly_beats_fcfs_on_burst_with_identical_invalidation() {
    let (cfg, pop, trace) = burst_setup();
    let burst = burst_round(&trace, cfg.rounds);
    let requests: Vec<UnlearnRequest> = trace.at(burst).to_vec();
    assert!(
        requests.len() >= 8,
        "seeded burst too small: {} requests (need ≥ 8 over ≤ {} lineages)",
        requests.len(),
        cfg.shards
    );

    // FCFS: one retrain pass per request, in arrival order.
    let mut fcfs = SystemVariant::Cause.build_cost(&cfg).unwrap();
    for _ in 1..=burst {
        fcfs.run_round(&pop).unwrap();
    }
    let mut fcfs_rsn = 0u64;
    let mut fcfs_invalidated: BTreeSet<(usize, u32)> = BTreeSet::new();
    for req in &requests {
        let out = fcfs.process_request(req).unwrap();
        fcfs_rsn += out.rsn;
        fcfs_invalidated.extend(out.invalidated_versions.iter().copied());
    }

    // Coalesce: the whole burst merged into one plan.
    let mut coal = SystemVariant::Cause.build_cost(&cfg).unwrap();
    for _ in 1..=burst {
        coal.run_round(&pop).unwrap();
    }
    let stale_ids: BTreeSet<_> = coal.store().iter().map(|c| c.id).collect();
    let plan = BatchPlan::collect(&mut coal, &requests);
    assert!(
        plan.coalesced_retrains() > 0,
        "burst of {} requests over ≤ {} lineages must merge retrains",
        requests.len(),
        cfg.shards
    );
    let out = coal.execute_plan(&plan).unwrap();
    coal.metrics.record_requests(requests.len() as u64, out.rsn);
    let coal_invalidated: BTreeSet<(usize, u32)> =
        out.invalidated_versions.iter().copied().collect();

    // Headline: strictly fewer samples replayed, same versions purged.
    assert!(
        out.rsn < fcfs_rsn,
        "coalesce RSN {} must be strictly below FCFS RSN {fcfs_rsn}",
        out.rsn
    );
    assert_eq!(
        coal_invalidated, fcfs_invalidated,
        "both policies must invalidate the identical poisoned versions"
    );

    // Exact-unlearning audit: no pre-batch checkpoint of a poisoned
    // version survives in the store (survivors at those coverages are the
    // freshly retrained replacements).
    for c in coal.store().iter() {
        if coal_invalidated.contains(&(c.lineage, c.covered_segments)) {
            assert!(
                !stale_ids.contains(&c.id),
                "stale poisoned checkpoint survived: lineage {} cover {}",
                c.lineage,
                c.covered_segments
            );
        }
    }

    // Both engines accounted every request.
    assert_eq!(fcfs.metrics.total_requests(), requests.len() as u64);
    assert_eq!(coal.metrics.total_requests(), requests.len() as u64);
}

#[test]
fn service_drain_batched_beats_fcfs_drain_end_to_end() {
    let (cfg, pop, trace) = burst_setup();

    let run = |policy: BatchPolicy| -> u64 {
        let engine = SystemVariant::Cause.build_cost(&cfg).unwrap();
        let mut svc =
            UnlearningService::new(engine).with_planner(BatchPlanner::new(policy, 0));
        for t in 1..=cfg.rounds {
            svc.ingest_round(&pop).unwrap();
            for req in trace.at(t) {
                svc.submit(req.clone());
            }
            svc.drain_batched().unwrap();
        }
        assert_eq!(svc.pending(), 0);
        assert_eq!(
            svc.engine().metrics.total_requests(),
            trace.total_requests() as u64
        );
        svc.engine().metrics.total_rsn()
    };

    let fcfs_rsn = run(BatchPolicy::Fcfs);
    let coal_rsn = run(BatchPolicy::Coalesce);
    assert!(
        coal_rsn < fcfs_rsn,
        "coalesced service RSN {coal_rsn} must be strictly below FCFS {fcfs_rsn}"
    );
}

#[test]
fn request_before_any_round_is_accounted_not_dropped() {
    let (cfg, pop, trace) = burst_setup();
    let req = trace.at(1).first().cloned().expect("burst trace has requests");

    let mut engine = SystemVariant::Cause.build_cost(&cfg).unwrap();
    // Served before any training round: nothing to retrain, but the
    // request must land in the round-0 metrics slot (previously both the
    // count and RSN silently vanished).
    let out = engine.process_request(&req).unwrap();
    assert_eq!(out.rsn, 0);
    assert_eq!(engine.metrics.total_requests(), 1);
    assert_eq!(engine.metrics.rsn_by_round.len(), 1);

    // Later rounds still open their own slots.
    engine.run_round(&pop).unwrap();
    assert_eq!(engine.metrics.rsn_by_round.len(), 2);
    engine.process_request(&req).unwrap();
    assert_eq!(engine.metrics.total_requests(), 2);
}

#[test]
fn prop_run_trace_rsn_equals_sum_of_request_outcomes() {
    use cause::testkit::forall;

    forall(
        0xBA7C4,
        12,
        |rng, size| {
            let users = 6 + (14.0 * size) as usize;
            let rounds = 1 + rng.range(0, 4) as u32;
            let prob = 0.2 + 0.5 * rng.f64();
            let seed = rng.next_u64() % 1_000_000;
            (users, rounds, prob, seed)
        },
        |(users, rounds, prob, seed)| {
            let cfg = ExperimentConfig {
                users: *users,
                rounds: *rounds,
                shards: 4,
                unlearn_prob: *prob,
                seed: *seed,
                ..Default::default()
            };
            let pop = EdgePopulation::generate(PopulationConfig {
                spec: cfg.dataset.scaled(6_000),
                users: cfg.users,
                rounds: cfg.rounds,
                size_sigma: 0.8,
                label_alpha: 0.5,
                arrival_prob: 0.7,
                seed: cfg.seed,
            });
            let trace = RequestTrace::generate(
                &pop,
                &TraceConfig::paper_default(cfg.seed ^ 0x7ace).with_prob(cfg.unlearn_prob),
            );

            // Twin A: the engine's own trace driver.
            let mut auto = SystemVariant::Cause.build_cost(&cfg).unwrap();
            auto.run_trace(&pop, &trace).unwrap();

            // Twin B: manual loop accumulating per-request outcomes.
            let mut manual = SystemVariant::Cause.build_cost(&cfg).unwrap();
            let mut sum = 0u64;
            let mut served = 0u64;
            for t in 1..=cfg.rounds.min(pop.rounds()) {
                manual.run_round(&pop).unwrap();
                for req in trace.at(t) {
                    sum += manual.process_request(req).unwrap().rsn;
                    served += 1;
                }
            }

            if auto.metrics.total_rsn() != sum {
                return Err(format!(
                    "run_trace RSN {} != sum of outcomes {sum}",
                    auto.metrics.total_rsn()
                ));
            }
            if auto.metrics.total_requests() != served {
                return Err(format!(
                    "run_trace requests {} != served {served}",
                    auto.metrics.total_requests()
                ));
            }
            if manual.metrics.total_rsn() != sum {
                return Err(format!(
                    "engine metrics RSN {} != sum of its own outcomes {sum}",
                    manual.metrics.total_rsn()
                ));
            }
            Ok(())
        },
    );
}

/// `Deadline { slo_ticks: 0 }` IS the FCFS service model: across random
/// small configurations, both policies driven identically produce
/// byte-identical window receipts, latency receipts, and totals.
#[test]
fn prop_deadline_zero_is_byte_identical_to_fcfs() {
    use cause::testkit::forall;

    forall(
        0xDEAD0,
        8,
        |rng, size| {
            let users = 6 + (12.0 * size) as usize;
            let rounds = 1 + rng.range(0, 4) as u32;
            let prob = 0.2 + 0.6 * rng.f64();
            let seed = rng.next_u64() % 1_000_000;
            (users, rounds, prob, seed)
        },
        |(users, rounds, prob, seed)| {
            let cfg = ExperimentConfig {
                users: *users,
                rounds: *rounds,
                shards: 4,
                unlearn_prob: *prob,
                seed: *seed,
                ..Default::default()
            };
            let pop = EdgePopulation::generate(PopulationConfig {
                spec: cfg.dataset.scaled(6_000),
                users: cfg.users,
                rounds: cfg.rounds,
                size_sigma: 0.8,
                label_alpha: 0.5,
                arrival_prob: 0.7,
                seed: cfg.seed,
            });
            let trace = RequestTrace::generate(
                &pop,
                &TraceConfig::paper_default(cfg.seed ^ 0x51).with_prob(cfg.unlearn_prob),
            );

            let run = |policy: BatchPolicy| {
                let engine = SystemVariant::Cause.build_cost(&cfg).unwrap();
                let mut svc = UnlearningService::new(engine)
                    .with_planner(BatchPlanner::new(policy, 0));
                for t in 1..=cfg.rounds {
                    svc.ingest_round(&pop).unwrap();
                    for req in trace.at(t) {
                        svc.submit(req.clone());
                    }
                    svc.drain_batched().unwrap();
                }
                assert_eq!(svc.pending(), 0);
                let m = svc.engine().metrics.clone();
                (format!("{:?}", svc.batch_log), m)
            };

            let (fcfs_log, fcfs_m) = run(BatchPolicy::Fcfs);
            let (slo0_log, slo0_m) = run(BatchPolicy::Deadline { slo_ticks: 0 });

            if fcfs_log != slo0_log {
                return Err(format!(
                    "batch receipts differ:\nfcfs: {fcfs_log}\nslo0: {slo0_log}"
                ));
            }
            if fcfs_m.latency != slo0_m.latency {
                return Err("latency receipts differ".to_string());
            }
            if fcfs_m.total_rsn() != slo0_m.total_rsn()
                || fcfs_m.total_requests() != slo0_m.total_requests()
                || fcfs_m.retrains_coalesced != slo0_m.retrains_coalesced
            {
                return Err(format!(
                    "totals differ: rsn {} vs {}, requests {} vs {}",
                    fcfs_m.total_rsn(),
                    slo0_m.total_rsn(),
                    fcfs_m.total_requests(),
                    slo0_m.total_requests()
                ));
            }
            Ok(())
        },
    );
}

/// Serial and parallel executors resolve chains through the same
/// `ChainResolver` against the plan-time store snapshot — under an
/// eviction-heavy store (tiny memory, FiboR replacing mid-plan) both paths
/// must produce identical warm-start chains, RSN, invalidation sets, and
/// final store contents.
#[test]
fn serial_and_parallel_chain_resolution_agree_under_eviction() {
    let (base_cfg, pop, trace) = burst_setup();
    // Shrink memory so the store evicts while the plan executes.
    let cfg = base_cfg.with_memory_gb(0.2);
    let burst = burst_round(&trace, cfg.rounds);
    let requests: Vec<UnlearnRequest> = trace.at(burst).to_vec();
    assert!(requests.len() >= 8, "seeded burst too small");

    let run = |mode: ExecMode| {
        let mut engine = SystemVariant::Cause.build_cost(&cfg).unwrap();
        engine.set_exec_mode(mode);
        for _ in 1..=burst {
            engine.run_round(&pop).unwrap();
        }
        let plan = BatchPlan::collect(&mut engine, &requests);
        assert!(plan.lineages.len() >= 2, "plan must span several lineages");
        let out = engine.execute_plan(&plan).unwrap();
        let store: Vec<(usize, u32)> = engine
            .store()
            .iter()
            .map(|c| (c.lineage, c.covered_segments))
            .collect();
        (out, store, engine.metrics.clone())
    };

    let (ser, ser_store, ser_m) = run(ExecMode::Serial);
    let (par, par_store, par_m) = run(ExecMode::Parallel);

    assert!(
        ser_m.ckpts_replaced > 0 || ser_m.ckpts_invalidated > 0,
        "workload must stress the store (replaced {}, invalidated {})",
        ser_m.ckpts_replaced,
        ser_m.ckpts_invalidated
    );
    assert_eq!(ser.rsn, par.rsn, "serial and parallel RSN must match");
    assert_eq!(ser.warm_covers, par.warm_covers, "warm-start chains must match");
    assert_eq!(ser.invalidated_versions, par.invalidated_versions);
    assert_eq!(ser.warm_starts, par.warm_starts);
    assert_eq!(ser.scratch_starts, par.scratch_starts);
    assert_eq!(ser.lineages_retrained, par.lineages_retrained);
    assert_eq!(ser_store, par_store, "final store contents must match");
    assert_eq!(ser_m.ckpts_replaced, par_m.ckpts_replaced);
    assert_eq!(ser_m.warm_retrains, par_m.warm_retrains);
    assert_eq!(ser_m.scratch_retrains, par_m.scratch_retrains);
}

/// A heavy-removal burst where per-request hints (replay every requested
/// sample, summed per request) far exceed the true coalesced plan cost:
/// merged-cost admission must serve the whole window in one go on a
/// battery that hint-sum gating would have judged insufficient.
fn heavy_removal_setup() -> (
    ExperimentConfig,
    EdgePopulation,
    Vec<UnlearnRequest>,
    Vec<u64>,
    f64,
    f64,
) {
    let (cfg, pop, _) = burst_setup();
    // No old-slot reach (age_decay 0) keeps chains confined to the burst
    // segment; heavy fractions make the hint sum dwarf the merged replay.
    let tcfg = TraceConfig {
        unlearn_prob: 0.95,
        block_incl_prob: 0.95,
        age_decay: 0.0,
        frac_range: (0.7, 0.9),
        seed: 33,
    };
    let trace = RequestTrace::generate(&pop, &tcfg);
    let requests: Vec<UnlearnRequest> = trace.at(1).to_vec();
    assert!(requests.len() >= 8, "heavy-removal burst too small: {}", requests.len());

    // Probe twin: identical deterministic build, used to price the plan.
    let mut probe = SystemVariant::Cause.build_cost(&cfg).unwrap();
    probe.run_round(&pop).unwrap();
    let plan = BatchPlan::collect(&mut probe, &requests);
    let energy = EnergyModel::for_model(&cfg.model);
    let epochs = cfg.epochs_per_round;
    let lineage_joules: Vec<f64> = probe
        .plan_lineage_rsn(&plan)
        .into_iter()
        .map(|rsn| energy.retrain_joules(rsn, epochs))
        .collect();
    let merged_j: f64 = lineage_joules.iter().sum();
    let hint_j: f64 = requests
        .iter()
        .map(|r| energy.retrain_joules(r.total_samples(), epochs))
        .sum();
    let costs = probe.plan_lineage_rsn(&plan);
    (cfg, pop, requests, costs, merged_j, hint_j)
}

#[test]
fn merged_cost_admission_serves_when_hints_would_defer() {
    let (cfg, pop, requests, _costs, merged_j, hint_j) = heavy_removal_setup();
    assert!(
        hint_j > merged_j * 1.5,
        "workload no longer exercises the hint-vs-merged gap: hints {hint_j:.0} J \
         vs merged {merged_j:.0} J"
    );

    // A battery that covers the merged plan but NOT the hint sum.
    let charge = merged_j * 1.02;
    assert!(charge < hint_j);
    let battery = Battery {
        capacity_j: charge,
        charge_j: charge,
        harvest_watts: 0.0,
        brownouts: 0,
    };
    let engine = SystemVariant::Cause.build_cost(&cfg).unwrap();
    let mut svc = UnlearningService::new(engine)
        .with_battery(battery)
        .with_planner(BatchPlanner::new(BatchPolicy::Coalesce, 0));
    svc.ingest_round(&pop).unwrap();
    for req in &requests {
        svc.submit(req.clone());
    }
    let served = svc.drain_batched().unwrap();
    assert_eq!(served, requests.len(), "whole window must serve in one pass");
    assert!(svc.batch_log.iter().all(|b| !b.deferred), "no deferrals expected");
    assert_eq!(svc.engine().metrics.batches, 1);
    assert_eq!(svc.carryover_requests(), 0);
    let b = svc.battery().unwrap();
    assert!(b.charge_j >= 0.0 && b.charge_j <= b.capacity_j);
    assert_eq!(b.brownouts, 0);
}

#[test]
fn battery_splits_plan_at_lineage_granularity() {
    let (cfg, pop, requests, costs, merged_j, _hint_j) = heavy_removal_setup();
    let energy = EnergyModel::for_model(&cfg.model);
    let epochs = cfg.epochs_per_round;
    let joules: Vec<f64> =
        costs.iter().map(|&rsn| energy.retrain_joules(rsn, epochs)).collect();
    assert!(joules.len() >= 2, "need several lineages to split across");
    let c0 = joules[0];
    let charge = c0 * 1.05;
    assert!(
        charge < c0 + joules[1],
        "second lineage must be unaffordable at the chosen charge"
    );

    let battery = Battery {
        capacity_j: merged_j * 2.0,
        charge_j: charge,
        harvest_watts: 1.0,
        brownouts: 0,
    };
    let engine = SystemVariant::Cause.build_cost(&cfg).unwrap();
    let mut svc = UnlearningService::new(engine)
        .with_battery(battery)
        .with_planner(BatchPlanner::new(BatchPolicy::Coalesce, 0));
    svc.ingest_round(&pop).unwrap();
    for req in &requests {
        svc.submit(req.clone());
    }

    // First drain: only the first lineage is affordable — the plan splits
    // at lineage granularity; no request is dropped and all are accounted
    // with the executed share.
    let served = svc.drain_batched().unwrap();
    assert_eq!(served, requests.len());
    assert_eq!(svc.pending(), 0);
    // The same drain also probes the parked share and logs its (starved)
    // deferral receipt; inspect the executed window's receipt.
    let first = svc
        .batch_log
        .iter()
        .rev()
        .find(|b| !b.deferred)
        .expect("an executed window receipt")
        .clone();
    assert_eq!(first.requests, requests.len());
    assert_eq!(first.lineages_retrained, 1, "affordable prefix is one lineage");
    assert_eq!(svc.engine().metrics.lineages_retrained, 1);
    assert_eq!(svc.engine().metrics.total_requests(), requests.len() as u64);
    // The unfunded lineages are parked (requests already counted), and
    // the parked share stays visible to shutdown loops via
    // carryover_lineages even though its request count is zero.
    assert_eq!(svc.carryover_requests(), 0);
    assert_eq!(svc.carryover_lineages(), joules.len() - 1);

    // Harvest, then the carried-over share replays.
    svc.harvest(merged_j * 2.0);
    svc.drain_batched().unwrap();
    assert_eq!(svc.carryover_lineages(), 0);
    assert_eq!(
        svc.engine().metrics.lineages_retrained,
        joules.len() as u64,
        "every lineage of the original plan eventually retrains"
    );
    // Requests are not double counted by the carryover window.
    assert_eq!(svc.engine().metrics.total_requests(), requests.len() as u64);
    // Total replay matches the probe's single-shot coalesced cost.
    let expected_rsn: u64 = costs.iter().sum();
    assert_eq!(svc.engine().metrics.total_rsn(), expected_rsn);
}
