//! Integration: the full stack (engine + PJRT trainer) on a tiny real
//! workload — skipped when `make artifacts` has not run.

use std::rc::Rc;
use std::sync::Arc;

use cause::config::ExperimentConfig;
use cause::coordinator::engine::EvalPolicy;
use cause::coordinator::system::SystemVariant;
use cause::data::catalog::CIFAR10;
use cause::data::dataset::{EdgePopulation, PopulationConfig};
use cause::data::trace::{RequestTrace, TraceConfig};
use cause::runtime::Runtime;
use cause::training::{PjrtTrainer, PjrtTrainerConfig};

fn runtime() -> Option<Rc<Runtime>> {
    let dir = std::path::Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts");
    if !dir.join("manifest.txt").exists() {
        eprintln!("SKIP: no artifacts (run `make artifacts`)");
        return None;
    }
    Some(Rc::new(Runtime::new(dir).expect("runtime")))
}

fn tiny_setup(
    rt: Rc<Runtime>,
    v: SystemVariant,
    seed: u64,
) -> (cause::coordinator::Engine, Arc<EdgePopulation>, RequestTrace) {
    let cfg = ExperimentConfig {
        users: 10,
        rounds: 3,
        shards: 2,
        unlearn_prob: 0.3,
        dataset: CIFAR10.scaled(600),
        seed,
        ..Default::default()
    };
    let pop = Arc::new(EdgePopulation::generate(PopulationConfig {
        spec: cfg.dataset.clone(),
        users: cfg.users,
        rounds: cfg.rounds,
        size_sigma: 0.6,
        label_alpha: 1.0,
        arrival_prob: 0.9,
        seed: cfg.seed,
    }));
    let trace = RequestTrace::generate(
        &pop,
        &TraceConfig::paper_default(cfg.seed ^ 1).with_prob(cfg.unlearn_prob),
    );
    let trainer = PjrtTrainer::new(
        rt,
        pop.clone(),
        PjrtTrainerConfig {
            variant: "mobilenetv2_c10".into(),
            max_epochs: 1,
            lr: 0.05,
            test_samples: 128,
            seed: cfg.seed,
        },
        cfg.shards,
        v.schedule(&cfg).final_keep(),
    )
    .expect("trainer");
    let engine = v
        .build_with_trainer(&cfg, Box::new(trainer), EvalPolicy::FinalRound)
        .expect("engine");
    (engine, pop, trace)
}

#[test]
fn real_training_system_learns_and_unlearns() {
    let Some(rt) = runtime() else { return };
    let (mut engine, pop, trace) = tiny_setup(rt, SystemVariant::Cause, 3);
    engine.run_trace(&pop, &trace).expect("trace run");
    let m = &engine.metrics;
    assert!(m.total_requests() > 0, "trace generated no requests");
    assert!(m.total_rsn() > 0);
    let acc = m.final_accuracy().expect("real trainer must report accuracy");
    assert!(
        acc > 0.15,
        "ensemble accuracy {acc} not above chance (0.1 for 10 classes)"
    );
}

#[test]
fn cause_checkpoints_are_sparse_sisa_dense() {
    let Some(rt) = runtime() else { return };
    let (mut cause_engine, pop, trace) = tiny_setup(rt.clone(), SystemVariant::Cause, 5);
    cause_engine.run_trace(&pop, &trace).unwrap();
    let (mut sisa_engine, pop2, trace2) = tiny_setup(rt, SystemVariant::Sisa, 5);
    sisa_engine.run_trace(&pop2, &trace2).unwrap();

    let avg_bytes = |e: &cause::coordinator::Engine| {
        let (n, total) = e
            .store()
            .iter()
            .fold((0u64, 0u64), |(n, t), c| (n + 1, t + c.size_bytes));
        total / n.max(1)
    };
    let cause_avg = avg_bytes(&cause_engine);
    let sisa_avg = avg_bytes(&sisa_engine);
    assert!(
        (cause_avg as f64) < (sisa_avg as f64) * 0.6,
        "RCMP checkpoints should be <60% of dense: {cause_avg} vs {sisa_avg}"
    );
    // And the stored params really are sparse tensors (decode the codec
    // payload back to host tensors to inspect them).
    let ckpt = cause_engine.store().iter().next().expect("checkpoint");
    let params = ckpt.params.as_ref().expect("real params").decode();
    let (nz, total) = params
        .iter()
        .filter(|p| p.dims.len() == 2 && p.len() >= 1024)
        .fold((0usize, 0usize), |(nz, t), p| (nz + p.nonzero_count(), t + p.len()));
    let frac = nz as f64 / total.max(1) as f64;
    assert!(frac < 0.45, "prunable weights should be ~30% dense, got {frac}");
}

#[test]
fn warm_start_resumes_from_checkpoint_params() {
    let Some(rt) = runtime() else { return };
    let (mut engine, pop, _trace) = tiny_setup(rt, SystemVariant::Cause, 7);
    engine.run_round(&pop).unwrap();
    engine.run_round(&pop).unwrap();
    // Unlearn part of a round-2 block: must warm-start (round-1 checkpoint
    // exists) and replay only the poisoned segment.
    let block = pop.blocks_at(2)[0].clone();
    let req = cause::data::trace::UnlearnRequest {
        round: 2,
        user: block.user,
        arrival_tick: 2,
        parts: vec![(block.id, 1.max(block.samples / 3))],
    };
    let out = engine.process_request(&req).unwrap();
    assert_eq!(out.scratch_starts, 0, "should warm start: {out:?}");
    assert!(out.warm_starts >= 1);
    // Replay is bounded by the affected lineage's segment-2 size.
    let lineage_total: u64 = (0..engine.lineages().len())
        .map(|l| engine.lineages().get(l).total_samples())
        .sum();
    assert!(out.rsn < lineage_total, "replay {} >= all data {}", out.rsn, lineage_total);
}
