//! Differential equivalence: the index-accelerated planner vs the scan
//! oracle it replaced.
//!
//! The engine's plan→price→execute path now runs on incremental indices
//! (lineage prefix sums, store coverage index). These tests drive full
//! eviction-heavy workloads and assert, window by window, that
//!
//! * `Engine::plan_lineage_rsn` prices every merged window exactly like
//!   the scan-based resolver (`Engine::resolve_plan_naive`),
//! * `Engine::execute_plan` produces byte-identical receipts (RSN,
//!   warm-start chains, invalidated sub-model versions) to the naive
//!   pre-resolution,
//! * the store coverage index and the lineage prefix sums agree with
//!   naive recomputation after every mutation.

use cause::config::ExperimentConfig;
use cause::coordinator::system::SystemVariant;
use cause::coordinator::Engine;
use cause::data::dataset::{EdgePopulation, PopulationConfig};
use cause::data::trace::{RequestTrace, TraceConfig};
use cause::unlearning::BatchPlan;

fn workload(seed: u64) -> (ExperimentConfig, EdgePopulation, RequestTrace) {
    let cfg = ExperimentConfig {
        users: 30,
        rounds: 12,
        shards: 4,
        unlearn_prob: 0.8,
        seed,
        ..Default::default()
    }
    // ~8 checkpoint slots for 4 lineages x 12 rounds: constant eviction.
    .with_memory_gb(0.25);
    let pop = EdgePopulation::generate(PopulationConfig {
        spec: cfg.dataset.scaled(10_000),
        users: cfg.users,
        rounds: cfg.rounds,
        size_sigma: 0.8,
        label_alpha: 0.5,
        arrival_prob: 0.8,
        seed: cfg.seed,
    });
    // High age_decay: requests reach old time slots, so chains mix
    // scratch starts, long replay ranges, and multi-step warm chaining —
    // the resolution shapes where index and scan could diverge.
    let trace = RequestTrace::generate(
        &pop,
        &TraceConfig {
            unlearn_prob: cfg.unlearn_prob,
            block_incl_prob: 0.9,
            age_decay: 0.5,
            frac_range: (0.1, 0.5),
            seed: cfg.seed ^ 0x7ace,
        },
    );
    (cfg, pop, trace)
}

/// Every indexed structure must agree with its naive recomputation.
fn assert_indices_match_scan(engine: &Engine) {
    let store = engine.store();
    assert_eq!(store.occupied(), store.occupied_scan(), "occupied counter diverged");
    let shards = engine.lineages().len();
    for l in 0..shards {
        let max_cover = engine.lineages().get(l).segment_count() + 1;
        for cover in 0..=max_cover {
            assert_eq!(
                store.best_checkpoint(l, cover).map(|c| c.id),
                store.best_checkpoint_scan(l, cover).map(|c| c.id),
                "best_checkpoint({l},{cover}) diverged from scan"
            );
        }
        assert_eq!(
            store.latest(l).map(|c| c.id),
            store.latest_scan(l).map(|c| c.id),
            "latest({l}) diverged from scan"
        );

        let lin = engine.lineages().get(l);
        let scan_total: u64 = lin.segments().iter().map(|s| s.samples()).sum();
        assert_eq!(lin.total_samples(), scan_total, "lineage {l}: cached total diverged");
        let n = lin.segment_count();
        for c in 0..=n {
            let scan_suffix: u64 =
                lin.segments().iter().skip(c as usize).map(|s| s.samples()).sum();
            assert_eq!(
                lin.replay_samples(c),
                scan_suffix,
                "lineage {l}: replay_samples({c}) diverged"
            );
            for t in c..=n {
                let scan_range: u64 = lin
                    .segments()
                    .iter()
                    .take(t as usize)
                    .skip(c as usize)
                    .map(|s| s.samples())
                    .sum();
                assert_eq!(
                    lin.replay_range_samples(c, t),
                    scan_range,
                    "lineage {l}: replay_range_samples({c},{t}) diverged"
                );
            }
        }
    }
}

/// CAUSE under FiboR eviction: coalesced windows priced and executed by
/// the indexed planner must match the scan oracle receipt for receipt.
#[test]
fn indexed_planner_matches_scan_oracle_under_eviction() {
    let (cfg, pop, trace) = workload(37);
    let mut engine = SystemVariant::Cause.build_cost(&cfg).unwrap();
    let mut checked_windows = 0;
    for t in 1..=cfg.rounds {
        engine.run_round(&pop).unwrap();
        assert_indices_match_scan(&engine);
        let reqs: Vec<_> = trace.at(t).to_vec();
        if reqs.is_empty() {
            continue;
        }
        let plan = BatchPlan::collect(&mut engine, &reqs);
        assert_indices_match_scan(&engine); // after sample removal
        if plan.is_empty() {
            continue;
        }
        // Price before executing: indexed probe == scan resolution.
        let naive = engine.resolve_plan_naive(&plan);
        let indexed_rsn = engine.plan_lineage_rsn(&plan);
        assert_eq!(indexed_rsn, naive.lineage_rsn, "round {t}: probe diverged");

        // Execute: receipts must equal the naive pre-resolution exactly.
        let outcome = engine.execute_plan(&plan).unwrap();
        assert_eq!(outcome.warm_covers, naive.warm_covers, "round {t}: warm chains");
        assert_eq!(
            outcome.invalidated_versions, naive.invalidated_versions,
            "round {t}: invalidation receipts"
        );
        assert_eq!(
            outcome.rsn,
            naive.lineage_rsn.iter().sum::<u64>(),
            "round {t}: total RSN"
        );
        engine.metrics.record_requests(reqs.len() as u64, outcome.rsn);
        assert_indices_match_scan(&engine); // after invalidate + re-store
        checked_windows += 1;
    }
    assert!(checked_windows >= 3, "workload produced too few windows");
    // The eviction machinery was actually exercised.
    assert!(engine.metrics.ckpts_replaced > 0, "store never evicted");
    assert!(engine.metrics.ckpts_invalidated > 0, "no versions invalidated");
    assert!(engine.metrics.total_rsn() > 0);
}

/// SISA (no-replacement, store fills and rejects): the `would_accept`
/// probe skips doomed snapshots, and its accounting stays identical to
/// the store-then-reject path while the planner equivalence holds.
#[test]
fn no_replacement_rejections_keep_receipts_identical() {
    let (cfg, pop, trace) = workload(91);
    let mut engine = SystemVariant::Sisa.build_cost(&cfg).unwrap();
    for t in 1..=cfg.rounds {
        engine.run_round(&pop).unwrap();
        assert_indices_match_scan(&engine);
        let reqs: Vec<_> = trace.at(t).to_vec();
        if reqs.is_empty() {
            continue;
        }
        let plan = BatchPlan::collect(&mut engine, &reqs);
        if plan.is_empty() {
            continue;
        }
        let naive = engine.resolve_plan_naive(&plan);
        assert_eq!(engine.plan_lineage_rsn(&plan), naive.lineage_rsn);
        let outcome = engine.execute_plan(&plan).unwrap();
        assert_eq!(outcome.warm_covers, naive.warm_covers);
        assert_eq!(outcome.invalidated_versions, naive.invalidated_versions);
        engine.metrics.record_requests(reqs.len() as u64, outcome.rsn);
        assert_indices_match_scan(&engine);
    }
    // The full store rejected snapshots (the probe path), and the engine
    // metric mirrors the store's own counter exactly.
    assert!(engine.metrics.ckpts_rejected > 0, "store never filled up");
    assert_eq!(engine.metrics.ckpts_rejected, engine.store().stats().rejected);
    assert_eq!(engine.store().stats().replaced, 0, "no-replacement must not evict");
}
