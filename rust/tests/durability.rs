//! Kill-point crash-injection harness for the durability subsystem.
//!
//! The workload exercises everything the acceptance criteria name: FiboR
//! eviction under a **byte-budget** store, deadline-free coalescing
//! windows, and a **battery-split carryover window** (an affordable
//! lineage prefix executes, the unfunded share parks). Against it we
//! assert the crash-consistency invariant:
//!
//! * `durability = log` is receipt-identical to `durability = off` at
//!   every operation boundary (journaling is observation-only);
//! * crashing at **every byte offset** of the write-ahead log — injected
//!   through [`FailpointFs`] — then recovering yields exactly the state of
//!   the last complete frame boundary: the post-state of event k, never a
//!   torn hybrid;
//! * recovering at any operation boundary and driving the remaining
//!   operations reproduces the uninterrupted run's final receipt byte for
//!   byte (policy counters, partitioner RNG, id sequences all continue);
//! * compaction (snapshot + log truncation) preserves receipts across a
//!   reopen, and `log+spill` restores checkpoint payload tensors
//!   bit-exactly;
//! * under a volatile write cache, power loss preserves exactly the
//!   fsync-barrier-covered prefix: `fsync = always` loses nothing,
//!   `group_commit` recovers the last sealed commit scope, and `never`
//!   keeps only what was durable at attach time;
//! * an injected fsync failure poisons the journal loudly — the next
//!   fallible entry point errors and nothing appends past the failure.
//!
//! The byte-offset sweep covers **every** offset when `CAUSE_FAULT_FULL=1`
//! (the CI main-push configuration); otherwise it samples with a prime
//! stride, always keeping every frame boundary and its neighbours.

use cause::config::ExperimentConfig;
use cause::coordinator::engine::EvalPolicy;
use cause::coordinator::system::SystemVariant;
use cause::data::catalog::CIFAR10;
use cause::data::dataset::{EdgePopulation, PopulationConfig};
use cause::data::trace::{RequestTrace, TraceConfig};
use cause::persist::frame::{frame_bounds, HEADER_LEN, LOG_MAGIC};
use cause::persist::{Durability, DurabilityMode, FsyncPolicy, MemFs, PersistFs as _};
use cause::sim::device::AI_CUBESAT;
use cause::sim::Battery;
use cause::testkit::FailpointFs;
use cause::training::{HostTrainer, HostTrainerConfig};
use cause::runtime::codec::CodecMode;
use cause::util::Json;
use cause::UnlearningService;

const WAL: &str = "wal-0.log";
const MANIFEST: &str = "MANIFEST.json";

/// One scripted service operation.
#[derive(Clone, Copy, Debug)]
enum Op {
    Ingest,
    SubmitAll(u32),
    Advance(u64),
    DrainBatched,
    Harvest(f64),
    Flush,
}

struct Workload {
    cfg: ExperimentConfig,
    pop: EdgePopulation,
    trace: RequestTrace,
    /// Initial battery charge (joules) — tuned so one window splits.
    charge: f64,
    ops: Vec<Op>,
}

fn script() -> Vec<Op> {
    vec![
        Op::Ingest,
        Op::SubmitAll(1),
        Op::DrainBatched,
        Op::Ingest,
        Op::SubmitAll(2),
        Op::Advance(1),
        Op::DrainBatched,
        Op::Harvest(50_000.0),
        Op::DrainBatched,
        Op::Ingest,
        Op::SubmitAll(3),
        Op::Advance(2),
        Op::DrainBatched,
        Op::Harvest(50_000.0),
        Op::Flush,
    ]
}

fn base_cfg() -> ExperimentConfig {
    let cfg = ExperimentConfig {
        users: 10,
        rounds: 3,
        shards: 4,
        unlearn_prob: 0.5,
        ..Default::default()
    };
    // Byte-budget mode sized to ~3 cost-model checkpoints, so FiboR must
    // evict through the byte-metered admission path.
    let engine = SystemVariant::Cause.build_cost(&cfg).expect("probe engine");
    let ckpt_bytes = cfg.memory_bytes / engine.store().capacity().max(1) as u64;
    let budget = ckpt_bytes * 3 + ckpt_bytes / 2;
    cfg.with_byte_budget(budget.max(1))
}

fn population(cfg: &ExperimentConfig) -> (EdgePopulation, RequestTrace) {
    let pop = EdgePopulation::generate(PopulationConfig {
        spec: CIFAR10.scaled(6_000),
        users: cfg.users,
        rounds: cfg.rounds,
        size_sigma: 0.8,
        label_alpha: 0.5,
        arrival_prob: 0.7,
        seed: 1101,
    });
    let trace =
        RequestTrace::generate(&pop, &TraceConfig::paper_default(17).with_prob(cfg.unlearn_prob));
    (pop, trace)
}

fn build(w: &Workload, durability: Option<Durability>) -> UnlearningService {
    let engine = SystemVariant::Cause.build_cost(&w.cfg).expect("engine");
    let mut battery = Battery::new(&AI_CUBESAT);
    battery.charge_j = w.charge;
    let mut svc = UnlearningService::new(engine).with_battery(battery);
    if let Some(d) = durability {
        svc.attach_durability(d).expect("attach durability");
    }
    svc
}

/// Apply one op; returns true when this drain observed a battery *split*
/// (requests all accounted, but an unfunded lineage share parked).
fn apply(svc: &mut UnlearningService, w: &Workload, op: &Op) -> bool {
    match op {
        Op::Ingest => {
            svc.ingest_round(&w.pop).expect("ingest");
        }
        Op::SubmitAll(round) => {
            for req in w.trace.at(*round) {
                svc.submit(req.clone());
            }
        }
        Op::Advance(t) => svc.advance(*t),
        Op::DrainBatched => {
            svc.drain_batched().expect("drain");
            return svc.carryover_requests() == 0 && svc.carryover_lineages() > 0;
        }
        Op::Harvest(s) => svc.harvest(*s),
        Op::Flush => {
            svc.flush_batched().expect("flush");
        }
    }
    false
}

/// Run the whole script in-memory; returns (receipts after each op
/// including the initial state, split observed anywhere).
fn run_reference(w: &Workload) -> (Vec<Json>, bool) {
    let mut svc = build(w, None);
    let mut receipts = vec![svc.state_receipt()];
    let mut split = false;
    for op in &w.ops {
        split |= apply(&mut svc, w, op);
        receipts.push(svc.state_receipt());
    }
    (receipts, split)
}

/// Find a charge that makes some window split at lineage granularity: an
/// affordable prefix executes, the rest carries over. Costs are
/// deterministic, so scanning fractions of the most expensive
/// unconstrained window always lands on one when plans span >1 lineage.
fn workload() -> Workload {
    let cfg = base_cfg();
    let (pop, trace) = population(&cfg);
    let ops = script();
    let probe = Workload { cfg: cfg.clone(), pop, trace, charge: AI_CUBESAT.battery_joules, ops };
    let max_window_j = {
        let mut svc = build(&probe, None);
        for op in &probe.ops {
            apply(&mut svc, &probe, op);
        }
        svc.batch_log
            .iter()
            .map(|b| b.est_joules)
            .fold(0.0f64, f64::max)
    };
    assert!(max_window_j > 0.0, "workload executed no windows");
    for step in 1..40 {
        let charge = max_window_j * (step as f64) / 40.0;
        let candidate = Workload { charge, ..clone_workload(&probe) };
        let (_, split) = run_reference(&candidate);
        if split {
            return candidate;
        }
    }
    panic!("no charge in the ladder produced a battery-split window");
}

fn clone_workload(w: &Workload) -> Workload {
    let (pop, trace) = population(&w.cfg);
    Workload { cfg: w.cfg.clone(), pop, trace, charge: w.charge, ops: w.ops.clone() }
}

fn mem_durability(fs: &MemFs) -> Durability {
    Durability::mem(DurabilityMode::Log, fs.clone(), 0)
}

/// Durability journaling through a crash-injecting filesystem.
fn fp_durability(fp: &FailpointFs, fsync: FsyncPolicy) -> Durability {
    Durability { mode: DurabilityMode::Log, fs: Box::new(fp.clone()), compact_every: 0, fsync }
}

/// Recover a fresh service from the given disk image; returns the receipt
/// and how many events replayed.
fn recover(w: &Workload, fs: &MemFs) -> (Json, u64) {
    let mut svc = build(w, None);
    let report = svc
        .attach_durability(mem_durability(fs))
        .expect("recovery attach");
    (svc.state_receipt(), report.events_replayed)
}

/// Disk image holding the manifest plus a byte-truncated log.
fn truncated_image(full_manifest: &[u8], log_prefix: &[u8]) -> MemFs {
    let fs = MemFs::new();
    fs.put(MANIFEST, full_manifest.to_vec());
    fs.put(WAL, log_prefix.to_vec());
    fs
}

/// The acceptance-criteria harness: off ≡ log, kill-points at every frame
/// boundary AND every torn-write byte offset, continuation equality.
#[test]
fn killpoints_at_every_byte_recover_to_boundary_states() {
    let w = workload();
    let (ref_receipts, split) = run_reference(&w);
    assert!(split, "workload must exercise a battery-split carryover window");

    // Durable run, capturing the log length at every op boundary. The
    // journaled service must stay receipt-identical to the in-memory
    // reference the whole way (durability = off is the baseline).
    let fs = MemFs::new();
    let mut durable = build(&w, Some(mem_durability(&fs)));
    let mut op_log_len = vec![fs.file(WAL).expect("wal created").len()];
    for (i, op) in w.ops.iter().enumerate() {
        apply(&mut durable, &w, op);
        assert_eq!(
            durable.state_receipt(),
            ref_receipts[i + 1],
            "durability=log diverged from off at op {i} ({op:?})"
        );
        op_log_len.push(fs.file(WAL).unwrap().len());
    }
    assert!(durable.durability_error().is_none());
    let full = fs.file(WAL).unwrap();
    let manifest = fs.file(MANIFEST).unwrap();

    // Clean-boundary recoveries: one per complete frame prefix.
    let mut boundaries = vec![HEADER_LEN];
    boundaries.extend(frame_bounds(&full, LOG_MAGIC));
    assert!(boundaries.len() > 10, "workload should log a meaningful event count");
    assert_eq!(*boundaries.last().unwrap(), full.len(), "no torn tail live");
    let boundary_receipts: Vec<Json> = boundaries
        .iter()
        .enumerate()
        .map(|(k, &end)| {
            let (receipt, replayed) = recover(&w, &truncated_image(&manifest, &full[..end]));
            assert_eq!(replayed, k as u64, "boundary {k} replay count");
            receipt
        })
        .collect();

    // Every op boundary must be a frame boundary whose recovered state is
    // the live (== reference) state at that op.
    for (i, &len) in op_log_len.iter().enumerate() {
        let k = boundaries
            .iter()
            .position(|&b| b == len)
            .unwrap_or_else(|| panic!("op {i} did not end on a frame boundary"));
        assert_eq!(
            boundary_receipts[k], ref_receipts[i],
            "recovered state at op {i} differs from the live run"
        );
    }

    // Kill-points: crash at every byte offset (torn-write injection via
    // FailpointFs), recover, and require exactly the pre-/post-event state
    // of the last complete frame — never anything in between. The full
    // sweep runs under CAUSE_FAULT_FULL=1 (CI main pushes); otherwise a
    // prime-stride sample plus every frame boundary and its neighbours —
    // the offsets where an off-by-one would live.
    let cuts: Vec<usize> = if std::env::var("CAUSE_FAULT_FULL").as_deref() == Ok("1") {
        (0..=full.len()).collect()
    } else {
        let mut cuts: Vec<usize> = (0..=full.len()).step_by(23).collect();
        cuts.extend(
            boundaries
                .iter()
                .flat_map(|&b| [b.saturating_sub(1), b, (b + 1).min(full.len())]),
        );
        cuts.push(full.len());
        cuts.sort_unstable();
        cuts.dedup();
        cuts
    };
    for cut in cuts {
        let k = boundaries.iter().filter(|&&b| b <= cut).count().saturating_sub(1);
        // Re-write the prefix through a FailpointFs armed at `cut` bytes
        // of log traffic: what lands is exactly full[..cut].
        let mem = MemFs::new();
        mem.put(MANIFEST, manifest.clone());
        mem.put(WAL, full[..HEADER_LEN.min(cut)].to_vec());
        let mut fp = FailpointFs::new(mem.clone());
        fp.set_budget(Some(cut.saturating_sub(HEADER_LEN) as u64));
        if cut > HEADER_LEN {
            fp.append(WAL, &full[HEADER_LEN..]).unwrap();
        }
        assert_eq!(mem.file(WAL).unwrap(), full[..cut].to_vec(), "failpoint cut {cut}");

        let (receipt, replayed) = recover(&w, &mem);
        assert_eq!(replayed, k as u64, "cut {cut}: replay count");
        assert_eq!(
            receipt, boundary_receipts[k],
            "cut {cut}: torn-write recovery must land on frame boundary {k}"
        );
    }
}

/// Fsync-barrier matrix under a volatile write cache: power loss keeps
/// exactly the barrier-covered log prefix. `Always` never loses an acked
/// event; `GroupCommit` recovers the last sealed commit scope (round
/// ingest / batched drain / flush — submits, clock ticks, and harvests
/// appended after the seal are gone); `Never` keeps only what was
/// durable at attach time — the documented non-guarantee.
#[test]
fn fsync_matrix_crash_recovers_the_barrier_covered_prefix() {
    let w = workload();
    let (ref_receipts, _) = run_reference(&w);
    let seals = |op: &Op| matches!(op, Op::Ingest | Op::DrainBatched | Op::Flush);

    for fsync in [FsyncPolicy::Never, FsyncPolicy::Always, FsyncPolicy::GroupCommit] {
        for crash_after in 0..=w.ops.len() {
            let mem = MemFs::new();
            let fp = FailpointFs::new(mem.clone());
            let mut svc = build(&w, None);
            svc.attach_durability(fp_durability(&fp, fsync)).expect("attach");
            // Attach-time files (log header, manifest) count as durable;
            // from here, appends only survive once a barrier covers them.
            fp.enable_volatile();

            let mut durable = 0; // index of the last barrier-covered receipt
            for (i, op) in w.ops[..crash_after].iter().enumerate() {
                apply(&mut svc, &w, op);
                durable = match fsync {
                    FsyncPolicy::Always => i + 1,
                    FsyncPolicy::GroupCommit if seals(op) => i + 1,
                    _ => durable,
                };
            }
            assert!(svc.durability_error().is_none(), "{fsync:?}: live run must stay clean");
            drop(svc);
            fp.crash_lose_unsynced();

            let (receipt, _) = recover(&w, &mem);
            assert_eq!(
                receipt, ref_receipts[durable],
                "{fsync:?}: crash after op {crash_after} must recover exactly the \
                 last barrier-covered state (op {durable})"
            );
        }
    }

    // And the barriers amortize: one GroupCommit run issues one barrier
    // per commit scope, far fewer than one per append (bench_persist
    // pins the exact ratio as a gated floor).
    let fp = FailpointFs::new(MemFs::new());
    let mut svc = build(&w, None);
    svc.attach_durability(fp_durability(&fp, FsyncPolicy::GroupCommit)).expect("attach");
    for op in &w.ops {
        apply(&mut svc, &w, op);
    }
    let (appended, fsyncs) = svc.journal_fsync_stats().expect("journal attached");
    assert!(appended > 0 && fsyncs > 0, "workload must append and seal");
    assert!(
        fsyncs < appended,
        "group commit must amortize barriers: {appended} appends / {fsyncs} fsyncs"
    );
}

/// An injected fsync failure poisons the journal: the failed barrier is
/// recorded as `fsync: ...`, the op that hit it still completes (the
/// seal runs after serving), every later fallible entry point errors,
/// and no further events append — durability degrades loudly, never
/// silently.
#[test]
fn injected_fsync_failure_poisons_the_journal() {
    let w = workload();
    let mem = MemFs::new();
    let fp = FailpointFs::new(mem.clone());
    let mut svc = build(&w, None);
    svc.attach_durability(fp_durability(&fp, FsyncPolicy::GroupCommit)).expect("attach");

    svc.ingest_round(&w.pop).expect("ingest seals its window cleanly");
    assert!(svc.durability_error().is_none());

    // Arm one sync failure. The submits below only dirty the window
    // (group commit defers the barrier), so the drain's seal is the
    // barrier that fails.
    fp.fail_next_syncs(1);
    for req in w.trace.at(1) {
        svc.submit(req.clone());
    }
    svc.drain_batched().expect("the drain that hits the barrier still completes");
    let err = svc
        .durability_error()
        .expect("a failed barrier must poison the journal")
        .to_string();
    assert!(err.starts_with("fsync:"), "poison must name the barrier: {err:?}");
    assert!(err.contains("injected fsync failure"), "{err:?}");

    // Everything appended before the failed barrier is on disk (the
    // cache was not volatile here — only the barrier call failed), so
    // recovery still lands on the live state.
    let (receipt, _) = recover(&w, &mem);
    assert_eq!(receipt, svc.state_receipt(), "recovery from the surviving image");

    // Fallible entry points refuse to proceed...
    let msg = format!("{:#}", svc.drain_batched().unwrap_err());
    assert!(msg.contains("durability journal failed earlier"), "{msg}");
    assert!(svc.sync_journal().is_err());
    assert!(svc.compact_now().is_err());
    // ...and nothing appends past the failure.
    let seq = svc.journal_seq();
    svc.advance(3);
    svc.harvest(1_000.0);
    for req in w.trace.at(2) {
        svc.submit(req.clone());
    }
    assert_eq!(svc.journal_seq(), seq, "poisoned journal must not append");
}

/// Recover at every op boundary, then drive the remaining ops: the final
/// receipt must equal the uninterrupted run's (policy counters,
/// partitioner RNG, and id sequences all continue exactly).
#[test]
fn recovery_then_continuation_matches_uninterrupted_run() {
    let w = workload();
    let (ref_receipts, _) = run_reference(&w);
    let final_receipt = ref_receipts.last().unwrap();

    let fs = MemFs::new();
    let mut durable = build(&w, Some(mem_durability(&fs)));
    let mut images = vec![fs.fork()];
    for op in &w.ops {
        apply(&mut durable, &w, op);
        images.push(fs.fork());
    }

    for (i, image) in images.iter().enumerate() {
        let mut svc = build(&w, None);
        svc.attach_durability(mem_durability(image)).expect("recover");
        assert_eq!(svc.state_receipt(), ref_receipts[i], "recovery at op {i}");
        for op in &w.ops[i..] {
            apply(&mut svc, &w, op);
        }
        assert_eq!(
            svc.state_receipt(),
            *final_receipt,
            "continuation from op {i} diverged from the uninterrupted run"
        );
    }
}

/// Auto-compaction: snapshots + log truncation are receipt-invisible, and
/// recovery from snapshot+tail equals recovery from the full log.
#[test]
fn compaction_is_receipt_invisible_and_bounds_the_log() {
    let w = workload();
    let (ref_receipts, _) = run_reference(&w);

    let fs = MemFs::new();
    let mut durable = build(&w, None);
    durable
        .attach_durability(Durability::mem(DurabilityMode::Log, fs.clone(), 4))
        .expect("attach");
    for (i, op) in w.ops.iter().enumerate() {
        apply(&mut durable, &w, op);
        assert_eq!(
            durable.state_receipt(),
            ref_receipts[i + 1],
            "compacting journal diverged at op {i}"
        );
        assert!(
            durable.journal_events() <= 4,
            "auto-compaction must bound the tail (got {})",
            durable.journal_events()
        );
    }
    drop(durable);

    let mut svc = build(&w, None);
    let report = svc
        .attach_durability(Durability::mem(DurabilityMode::Log, fs.clone(), 4))
        .expect("recover");
    assert!(report.snapshot_loaded, "compaction must have produced a snapshot");
    assert!(report.events_replayed <= 4);
    assert_eq!(svc.state_receipt(), *ref_receipts.last().unwrap());

    // An explicit compaction right after recovery is also invisible.
    svc.compact_now().expect("compact");
    assert_eq!(svc.journal_events(), 0);
    drop(svc);
    let mut reopened = build(&w, None);
    reopened
        .attach_durability(Durability::mem(DurabilityMode::Log, fs, 4))
        .expect("reopen");
    assert_eq!(reopened.state_receipt(), *ref_receipts.last().unwrap());
}

/// `log+spill` restores checkpoint payload tensors bit-exactly (delta
/// chains re-share parents, so identity-keyed byte accounting — pinned
/// parents included — survives); plain `log` restores all accounting
/// without payloads, which is exact for self-contained codecs (sparse:
/// charged bytes == declared sizes). A delta codec without spill would
/// under-count evicted-but-pinned parents after recovery, which is why
/// the pairing below is the supported matrix.
#[test]
fn spill_recovers_checkpoint_payloads_bit_exactly() {
    let shapes = vec![vec![24, 24], vec![24]];
    let dense = cause::training::host::dense_upper_bound(&shapes);
    let cfg_with = |codec: CodecMode| {
        ExperimentConfig {
            users: 8,
            rounds: 3,
            shards: 3,
            unlearn_prob: 0.5,
            ..Default::default()
        }
        .with_byte_budget(dense * 3)
        .with_codec(codec)
    };
    let (pop, trace) = population(&cfg_with(CodecMode::Sparse));
    let build_host = |cfg: &ExperimentConfig| {
        let trainer = HostTrainer::new(
            HostTrainerConfig { shapes: shapes.clone(), seed: 5, update_frac: 0.2 },
            cfg.shards,
            SystemVariant::Cause.schedule(cfg),
        );
        let engine = SystemVariant::Cause
            .build_with_trainer(cfg, Box::new(trainer), EvalPolicy::Never)
            .expect("host engine");
        UnlearningService::new(engine)
    };

    for (codec, mode, expect_payloads) in [
        (CodecMode::Delta, DurabilityMode::LogSpill, true),
        (CodecMode::Sparse, DurabilityMode::Log, false),
    ] {
        let cfg = cfg_with(codec);
        let fs = MemFs::new();
        let mut svc = build_host(&cfg);
        svc.attach_durability(Durability::mem(mode, fs.clone(), 0)).expect("attach");
        for t in 1..=cfg.rounds {
            svc.ingest_round(&pop).expect("ingest");
            for req in trace.at(t) {
                svc.submit(req.clone());
            }
            svc.drain_batched().expect("drain");
        }
        let live_receipt = svc.state_receipt();
        let live_payloads: Vec<(u64, Option<Vec<cause::runtime::HostTensor>>)> = svc
            .engine()
            .store()
            .iter()
            .map(|c| (c.id.0, c.params.as_ref().map(|p| p.decode())))
            .collect();
        assert!(
            live_payloads.iter().any(|(_, p)| p.is_some()),
            "host workload must store real payloads"
        );
        drop(svc);

        let mut recovered = build_host(&cfg);
        recovered.attach_durability(Durability::mem(mode, fs, 0)).expect("recover");
        assert_eq!(recovered.state_receipt(), live_receipt, "{mode:?} receipts");
        let rec_payloads: Vec<(u64, Option<Vec<cause::runtime::HostTensor>>)> = recovered
            .engine()
            .store()
            .iter()
            .map(|c| (c.id.0, c.params.as_ref().map(|p| p.decode())))
            .collect();
        if expect_payloads {
            assert_eq!(rec_payloads, live_payloads, "spilled payloads bit-exact");
        } else {
            assert_eq!(
                rec_payloads.iter().map(|(id, _)| *id).collect::<Vec<_>>(),
                live_payloads.iter().map(|(id, _)| *id).collect::<Vec<_>>(),
                "log mode keeps the layout"
            );
            assert!(
                rec_payloads.iter().all(|(_, p)| p.is_none()),
                "log mode must not fabricate payloads"
            );
        }
    }
}
