//! Fleet service equivalence and routing-locality properties.
//!
//! The keystone invariant of the sharded fleet: `fleet_workers = 1` must
//! replay the unsharded [`UnlearningService`] **byte-identically** — the
//! state receipt (queue, carryover, battery, lineages, store stats,
//! receipt logs, metrics JSON), the journal event stream, and the WAL
//! bytes on the backing filesystem — over a workload that exercises
//! FiboR eviction, a byte-budget store, battery-split windows, and
//! durability journaling all at once.
//!
//! Alongside it: the routing layer's locality invariant (a user frozen
//! onto a shard keeps routing there across arbitrary grow/shrink
//! sequences), the seed-derivation audit (per-shard engine seeds are a
//! deterministic function of the routing seed, exposed in the fleet
//! receipt), multi-worker conservation (every request served exactly
//! once, fleet metrics = sum of shard metrics), and per-shard journal
//! recovery.

use cause::config::ExperimentConfig;
use cause::coordinator::system::SystemVariant;
use cause::data::dataset::{EdgePopulation, PopulationConfig, UserId};
use cause::data::trace::{RequestTrace, TraceConfig};
use cause::fleet::{FleetService, Router};
use cause::memory::StoreMeter;
use cause::persist::{Durability, DurabilityMode, MemFs};
use cause::sim::device::AI_CUBESAT;
use cause::sim::Battery;
use cause::testkit::forall;
use cause::unlearning::UnlearningService;

/// FiboR + byte-budget + battery-split workload (the acceptance shape):
/// CAUSE under constant byte-metered eviction, with a battery small
/// enough that some windows starve or split at lineage granularity.
fn workload(seed: u64) -> (ExperimentConfig, EdgePopulation, RequestTrace) {
    let mut cfg = ExperimentConfig {
        users: 20,
        rounds: 6,
        shards: 4,
        unlearn_prob: 0.7,
        seed,
        ..Default::default()
    };
    // Byte-metered C_m, sized for constant admission/eviction pressure.
    cfg.memory_bytes = 64 * 1024;
    cfg.store_meter = StoreMeter::Bytes;
    let pop = EdgePopulation::generate(PopulationConfig {
        spec: cfg.dataset.scaled(8_000),
        users: cfg.users,
        rounds: cfg.rounds,
        size_sigma: 0.8,
        label_alpha: 0.5,
        arrival_prob: 0.8,
        seed: cfg.seed,
    });
    let trace = RequestTrace::generate(
        &pop,
        &TraceConfig {
            unlearn_prob: cfg.unlearn_prob,
            block_incl_prob: 0.8,
            age_decay: 0.5,
            frac_range: (0.1, 0.5),
            seed: cfg.seed ^ 0xf1ee7,
        },
    );
    (cfg, pop, trace)
}

/// A battery low enough to starve / split some windows but harvestable
/// back to life between rounds.
fn tight_battery(charge_j: f64) -> Battery {
    let mut b = Battery::new(&AI_CUBESAT);
    b.charge_j = charge_j;
    b
}

/// The service surface the differential driver needs — implemented by
/// both sides so each gets *exactly* the same schedule.
trait Drive {
    fn ingest(&mut self, pop: &EdgePopulation) -> Result<(), String>;
    fn advance(&mut self, ticks: u64);
    fn submit(&mut self, req: &cause::data::trace::UnlearnRequest);
    fn drain(&mut self, flush: bool) -> Result<usize, String>;
    fn harvest(&mut self, secs: f64);
}

impl Drive for UnlearningService {
    fn ingest(&mut self, pop: &EdgePopulation) -> Result<(), String> {
        self.ingest_round(pop).map_err(|e| format!("{e:#}"))
    }
    fn advance(&mut self, ticks: u64) {
        UnlearningService::advance(self, ticks);
    }
    fn submit(&mut self, req: &cause::data::trace::UnlearnRequest) {
        UnlearningService::submit(self, req.clone());
    }
    fn drain(&mut self, flush: bool) -> Result<usize, String> {
        if flush { self.flush_batched() } else { self.drain_batched() }
            .map_err(|e| format!("{e:#}"))
    }
    fn harvest(&mut self, secs: f64) {
        UnlearningService::harvest(self, secs);
    }
}

impl Drive for FleetService {
    fn ingest(&mut self, pop: &EdgePopulation) -> Result<(), String> {
        self.ingest_round(pop).map_err(|e| format!("{e:#}"))
    }
    fn advance(&mut self, ticks: u64) {
        FleetService::advance(self, ticks);
    }
    fn submit(&mut self, req: &cause::data::trace::UnlearnRequest) {
        FleetService::submit(self, req.clone());
    }
    fn drain(&mut self, flush: bool) -> Result<usize, String> {
        if flush { self.flush_batched() } else { self.drain_batched() }
            .map_err(|e| format!("{e:#}"))
    }
    fn harvest(&mut self, secs: f64) {
        FleetService::harvest(self, secs);
    }
}

/// Drive one side of the differential run: per round — ingest, clock
/// skew, submits, batched drain, a harvest; then a flush, a big harvest,
/// and a final drain to replay any battery-deferred carryover.
fn drive(
    side: &mut impl Drive,
    rounds: u32,
    pop: &EdgePopulation,
    trace: &RequestTrace,
) -> Result<usize, String> {
    let mut served = 0;
    for t in 1..=rounds {
        side.ingest(pop)?;
        side.advance(u64::from(t) % 3);
        for req in trace.at(t) {
            side.submit(req);
        }
        served += side.drain(false)?;
        side.harvest(40.0);
    }
    served += side.drain(true)?;
    side.harvest(1e7);
    served += side.drain(false)?;
    Ok(served)
}

/// Keystone: a 1-worker fleet replays the unsharded service
/// byte-identically — receipts, metrics JSON, journal events, WAL bytes.
#[test]
fn fleet_of_one_replays_unsharded_byte_identically() {
    forall(
        0xf1ee7_0001,
        5,
        |rng, _size| (rng.next_u64(), 120.0 + (rng.next_u64() % 300) as f64),
        |&(seed, charge)| {
            let (mut cfg, pop, trace) = workload(seed);
            cfg.fleet_workers = 1;

            // Unsharded reference, journaling to its own MemFs.
            let fs_ref = MemFs::new();
            let mut svc = SystemVariant::Cause
                .build_service(&cfg)
                .map_err(|e| format!("build_service: {e:#}"))?
                .with_battery(tight_battery(charge));
            svc.attach_durability(Durability::mem(DurabilityMode::Log, fs_ref.clone(), 0))
                .map_err(|e| format!("attach (unsharded): {e:#}"))?;

            // 1-worker fleet, journaling to a parallel MemFs.
            let fs_fleet = MemFs::new();
            let mut fleet = SystemVariant::Cause
                .build_fleet(&cfg)
                .map_err(|e| format!("build_fleet: {e:#}"))?
                .with_battery(tight_battery(charge));
            fleet
                .attach_durability(vec![Durability::mem(
                    DurabilityMode::Log,
                    fs_fleet.clone(),
                    0,
                )])
                .map_err(|e| format!("attach (fleet): {e:#}"))?;

            let served_ref = drive(&mut svc, cfg.rounds, &pop, &trace)?;
            let served_fleet = drive(&mut fleet, cfg.rounds, &pop, &trace)?;

            if served_ref != served_fleet {
                return Err(format!("served diverged: {served_ref} vs {served_fleet}"));
            }
            let receipt_ref = svc.state_receipt().to_string();
            let receipt_fleet = fleet
                .state_receipt()
                .map_err(|e| format!("fleet receipt: {e:#}"))?
                .to_string();
            if receipt_ref != receipt_fleet {
                return Err(format!(
                    "state receipts diverged:\n  unsharded: {receipt_ref}\n  fleet:     {receipt_fleet}"
                ));
            }
            let m_ref = svc.engine().metrics.to_json().to_string();
            let m_fleet = fleet
                .metrics()
                .map_err(|e| format!("fleet metrics: {e:#}"))?
                .to_json()
                .to_string();
            if m_ref != m_fleet {
                return Err(format!("metrics diverged:\n  {m_ref}\n  {m_fleet}"));
            }
            let ev_ref = svc.journal_events();
            let ev_fleet =
                fleet.journal_events().map_err(|e| format!("fleet events: {e:#}"))?;
            if ev_ref != ev_fleet {
                return Err(format!("journal events diverged: {ev_ref} vs {ev_fleet}"));
            }
            // Metrics registry: the 1-worker fleet's registry (returned
            // verbatim from its single shard, no merge pass) must be
            // byte-identical to the unsharded service's.
            let reg_ref = svc.registry().to_json().to_string();
            let reg_fleet = fleet
                .registry()
                .map_err(|e| format!("fleet registry: {e:#}"))?
                .to_json()
                .to_string();
            if reg_ref != reg_fleet {
                return Err(format!(
                    "registries diverged:\n  unsharded: {reg_ref}\n  fleet:     {reg_fleet}"
                ));
            }
            // WAL bytes: same file set, same contents.
            let files_ref = fs_ref.sizes();
            let files_fleet = fs_fleet.sizes();
            if files_ref != files_fleet {
                return Err(format!(
                    "WAL file sets diverged: {files_ref:?} vs {files_fleet:?}"
                ));
            }
            for (name, _) in &files_ref {
                if fs_ref.file(name) != fs_fleet.file(name) {
                    return Err(format!("WAL bytes diverged in {name}"));
                }
            }
            Ok(())
        },
    );
}

/// Multi-worker conservation: every submitted request is served exactly
/// once somewhere, and the fleet aggregate equals the sum of the shards.
#[test]
fn two_worker_fleet_conserves_requests() {
    let (mut cfg, pop, trace) = workload(91);
    cfg.fleet_workers = 2;
    let mut fleet = SystemVariant::Cause.build_fleet(&cfg).unwrap();
    let mut submitted = 0usize;
    for t in 1..=cfg.rounds {
        fleet.ingest_round(&pop).unwrap();
        for req in trace.at(t) {
            // Locality: the request must route to the shard holding the
            // user's ingested data.
            let home = fleet.shard_of(req.user).expect("user was routed at ingest");
            fleet.submit(req.clone());
            assert_eq!(fleet.shard_of(req.user), Some(home));
            submitted += 1;
        }
        fleet.drain_batched().unwrap();
    }
    let flushed = fleet.flush_batched().unwrap();
    assert!(flushed <= submitted);
    assert!(submitted > 0, "workload produced no requests");
    assert_eq!(fleet.pending().unwrap(), 0);
    assert_eq!(fleet.carryover_lineages().unwrap(), 0, "mains: nothing parked");

    let shard_metrics = fleet.shard_metrics().unwrap();
    assert_eq!(shard_metrics.len(), 2);
    let total: u64 = shard_metrics.iter().map(|m| m.total_requests()).sum();
    assert_eq!(total, submitted as u64, "each request served exactly once");
    // Both shards did real work under this trace.
    assert!(
        shard_metrics.iter().all(|m| m.total_requests() > 0),
        "routing sent every request to one shard: {:?}",
        shard_metrics.iter().map(|m| m.total_requests()).collect::<Vec<_>>()
    );
    let fleet_m = fleet.metrics().unwrap();
    assert_eq!(fleet_m.total_requests(), total);
    assert_eq!(
        fleet_m.total_rsn(),
        shard_metrics.iter().map(|m| m.total_rsn()).sum::<u64>()
    );
    let batch_requests: usize =
        fleet.batch_log().unwrap().iter().map(|b| b.requests).sum();
    assert_eq!(batch_requests, submitted);

    // The fleet-level registry is exactly the shard registries merged in
    // shard order — counters sum, histograms merge, and the merged
    // request counter agrees with the metrics aggregate above.
    let shard_regs = fleet.shard_registries().unwrap();
    assert_eq!(shard_regs.len(), 2);
    let mut merged = shard_regs[0].clone();
    for r in &shard_regs[1..] {
        merged.merge(r);
    }
    let fleet_reg = fleet.registry().unwrap();
    assert_eq!(
        fleet_reg.to_json().to_string(),
        merged.to_json().to_string(),
        "fleet registry must equal the in-order merge of shard registries"
    );
    assert_eq!(fleet_reg.counter("req.requests"), total);
}

/// Satellite: per-shard seeds derive deterministically from the routing
/// seed, shard 0 keeps the root seed, and the fleet receipt exposes the
/// derivation for recovery audits.
#[test]
fn shard_seeds_are_derived_and_auditable() {
    let seeds_a = FleetService::derive_shard_seeds(42, 4);
    let seeds_b = FleetService::derive_shard_seeds(42, 4);
    assert_eq!(seeds_a, seeds_b, "derivation must be deterministic");
    assert_eq!(seeds_a[0], 42, "shard 0 runs the root seed");
    let mut uniq = seeds_a.clone();
    uniq.sort_unstable();
    uniq.dedup();
    assert_eq!(uniq.len(), 4, "shard seeds must be distinct: {seeds_a:?}");
    // Prefix-stable: growing the fleet keeps existing shards' seeds.
    assert_eq!(
        FleetService::derive_shard_seeds(42, 2),
        seeds_a[..2].to_vec(),
        "derivation must be prefix-stable across fleet sizes"
    );

    let (mut cfg, pop, _trace) = workload(7);
    cfg.seed = 42;
    cfg.fleet_workers = 4;
    let mut fleet = SystemVariant::Cause.build_fleet(&cfg).unwrap();
    fleet.ingest_round(&pop).unwrap();
    let receipt = fleet.state_receipt().unwrap().to_string();
    for s in &seeds_a {
        assert!(
            receipt.contains(&format!("{s:#018x}")),
            "fleet receipt must expose shard seed {s:#018x}"
        );
    }
    assert!(receipt.contains("routing"), "fleet receipt carries routing state");
    assert!(receipt.contains("epoch"), "fleet receipt carries the routing epoch");
}

/// Satellite: routing locality under shrink/re-home. Over random
/// grow/shrink sequences, a user's first-assigned shard is their home
/// forever — frozen-shard users still route to the shard holding their
/// past data — and new users always land inside the active range.
#[test]
fn routing_stays_local_across_random_shrink_sequences() {
    forall(
        0xf1ee7_0002,
        40,
        |rng, size| {
            let workers = 2 + (rng.next_u64() % 6) as usize; // 2..=7
            let steps = 5 + (60.0 * size) as usize;
            let ops: Vec<(u64, u64, u64)> = (0..steps)
                .map(|_| (rng.next_u64() % 3, rng.next_u64() % 40, 1 + rng.next_u64() % 5000))
                .collect();
            (rng.next_u64(), workers, ops)
        },
        |&(seed, workers, ref ops)| {
            let mut router = Router::new(workers, seed);
            let mut homes: Vec<Option<usize>> = vec![None; 40];
            for &(op, user, size) in ops {
                match op {
                    // Route traffic for a (possibly known) user.
                    0 | 1 => {
                        let u = UserId(user as u32);
                        let s = router.route(u, size);
                        match homes[user as usize] {
                            None => {
                                if s >= router.active() {
                                    return Err(format!(
                                        "new user {user} landed on shard {s}, outside \
                                         active range {}",
                                        router.active()
                                    ));
                                }
                                homes[user as usize] = Some(s);
                            }
                            Some(home) => {
                                if s != home {
                                    return Err(format!(
                                        "user {user} re-homed {home} -> {s} (epoch {})",
                                        router.epoch()
                                    ));
                                }
                            }
                        }
                        if router.lookup(u) != Some(s) {
                            return Err(format!("lookup disagrees with route for {user}"));
                        }
                    }
                    // Shrink or re-widen the active range.
                    _ => router.set_active(1 + (size as usize % workers)),
                }
            }
            Ok(())
        },
    );
}

/// Per-shard journals recover independently: rebuild a 2-worker fleet
/// from its shards' WALs and land on the identical fleet receipt.
#[test]
fn fleet_recovers_from_per_shard_journals() {
    let (mut cfg, pop, trace) = workload(23);
    cfg.fleet_workers = 2;
    let fs0 = MemFs::new();
    let fs1 = MemFs::new();
    let mut fleet = SystemVariant::Cause.build_fleet(&cfg).unwrap();
    fleet
        .attach_durability(vec![
            Durability::mem(DurabilityMode::Log, fs0.clone(), 0),
            Durability::mem(DurabilityMode::Log, fs1.clone(), 0),
        ])
        .unwrap();
    for t in 1..=cfg.rounds {
        fleet.ingest_round(&pop).unwrap();
        for req in trace.at(t) {
            fleet.submit(req.clone());
        }
        fleet.drain_batched().unwrap();
    }
    fleet.flush_batched().unwrap();
    let receipt_before = fleet.state_receipt().unwrap().to_string();
    drop(fleet); // crash

    let mut recovered = SystemVariant::Cause.build_fleet(&cfg).unwrap();
    let reports = recovered
        .attach_durability(vec![
            Durability::mem(DurabilityMode::Log, fs0.fork(), 0),
            Durability::mem(DurabilityMode::Log, fs1.fork(), 0),
        ])
        .unwrap();
    assert!(reports.iter().all(|r| r.events_replayed > 0 || r.snapshot_loaded));
    // The fleet receipt covers routing *config* (seed/epoch/active) and
    // full per-shard state; sticky assignments live in each engine's
    // recovered partitioner state, so no extra replay is needed here.
    let receipt_after = recovered.state_receipt().unwrap().to_string();
    assert_eq!(receipt_before, receipt_after, "per-shard recovery diverged");
}
