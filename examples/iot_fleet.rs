//! IoT fleet scenario: a fleet of heterogeneous edge devices each running
//! CAUSE locally, with fleet-level reporting — the shape of a real
//! deployment (health monitors, traffic cameras) where every device owns
//! its users' data and must serve their unlearning requests locally.
//!
//! Devices differ in memory envelope and workload intensity; the fleet
//! report shows how CAUSE's RSN scales across the envelope spectrum and
//! which devices would fall behind under SISA instead.
//!
//! ```bash
//! cargo run --release --example iot_fleet
//! ```

use cause::config::profiles;
use cause::config::ExperimentConfig;
use cause::coordinator::system::SystemVariant;
use cause::experiments::common;
use cause::util::Table;

struct Device {
    name: &'static str,
    memory_gb: f64,
    users: usize,
    unlearn_prob: f64,
    model: cause::config::ModelProfile,
}

const FLEET: [Device; 4] = [
    Device {
        name: "traffic-cam-01",
        memory_gb: 2.0,
        users: 100,
        unlearn_prob: 0.1,
        model: profiles::RESNET34,
    },
    Device {
        name: "health-hub-02",
        memory_gb: 1.0,
        users: 60,
        unlearn_prob: 0.3,
        model: profiles::MOBILENETV2,
    },
    Device {
        name: "retail-edge-03",
        memory_gb: 0.5,
        users: 80,
        unlearn_prob: 0.2,
        model: profiles::DENSENET121,
    },
    Device {
        name: "drone-relay-04",
        memory_gb: 0.5,
        users: 30,
        unlearn_prob: 0.5,
        model: profiles::MOBILENETV2,
    },
];

fn main() -> anyhow::Result<()> {
    let mut table = Table::new(
        "fleet report: CAUSE vs SISA per device (10 rounds)",
        &[
            "device", "model", "mem", "slots(CAUSE)", "slots(SISA)", "requests",
            "RSN CAUSE", "RSN SISA", "speedup", "energy CAUSE (J)", "energy SISA (J)",
        ],
    );
    for dev in FLEET {
        let cfg = ExperimentConfig {
            users: dev.users,
            unlearn_prob: dev.unlearn_prob,
            model: dev.model,
            seed: 17,
            ..Default::default()
        }
        .with_memory_gb(dev.memory_gb);

        let cause_engine = SystemVariant::Cause.build_cost(&cfg)?;
        let sisa_engine = SystemVariant::Sisa.build_cost(&cfg)?;
        let slots_cause = cause_engine.store().capacity();
        let slots_sisa = sisa_engine.store().capacity();

        let cause = common::run_cost(SystemVariant::Cause, &cfg)?;
        let sisa = common::run_cost(SystemVariant::Sisa, &cfg)?;
        table.row(vec![
            dev.name.into(),
            dev.model.name.into(),
            format!("{:.1}GB", dev.memory_gb),
            slots_cause.to_string(),
            slots_sisa.to_string(),
            cause.total_requests().to_string(),
            cause.total_rsn().to_string(),
            sisa.total_rsn().to_string(),
            format!("{:.2}x", sisa.total_rsn() as f64 / cause.total_rsn().max(1) as f64),
            format!("{:.0}", cause.energy_joules),
            format!("{:.0}", sisa.energy_joules),
        ]);
    }
    println!("{}", table.render());

    // Fleet-level takeaway: devices where exact unlearning is only feasible
    // with CAUSE (SISA exceeding a 2x energy budget).
    println!(
        "devices where SISA costs >2x CAUSE's energy: {}",
        table
            .rows
            .iter()
            .filter(|r| {
                let c: f64 = r[9].parse().unwrap_or(0.0);
                let s: f64 = r[10].parse().unwrap_or(0.0);
                s > 2.0 * c
            })
            .map(|r| r[0].as_str())
            .collect::<Vec<_>>()
            .join(", ")
    );
    Ok(())
}
