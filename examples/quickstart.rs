//! Quickstart: build a CAUSE system, feed it data, unlearn a user's data,
//! and inspect what happened.
//!
//! ```bash
//! cargo run --release --example quickstart
//! ```
//!
//! This example uses the accounting backend (no artifacts required); see
//! `e2e_edge_unlearning.rs` for the full PJRT-executed pipeline.

use cause::config::ExperimentConfig;
use cause::coordinator::system::SystemVariant;
use cause::data::trace::{RequestTrace, TraceConfig};
use cause::experiments::common;
use cause::persist::{Durability, DurabilityMode, FsyncPolicy, MemFs};
use cause::unlearning::UnlearningService;

fn main() -> anyhow::Result<()> {
    // 1. Configure the device: paper defaults, smaller population for demo.
    let cfg = ExperimentConfig {
        users: 40,
        rounds: 6,
        shards: 4,
        unlearn_prob: 0.2,
        ..Default::default()
    };
    println!(
        "device: C_m={:.1} GB, model={} ({} MB dense, {} MB pruned at keep={})",
        cfg.memory_bytes as f64 / (1u64 << 30) as f64,
        cfg.model.name,
        cfg.model.file_mb,
        cfg.model.pruned_bytes(cfg.prune_keep) / (1024 * 1024),
        cfg.prune_keep
    );

    // 2. Synthesize the edge population and its unlearning request trace.
    let pop = common::population(&cfg);
    let trace = RequestTrace::generate(
        &pop,
        &TraceConfig::paper_default(7).with_prob(cfg.unlearn_prob),
    );
    println!(
        "population: {} users, {} samples over {} rounds; {} unlearning requests",
        cfg.users,
        pop.total_samples(),
        cfg.rounds,
        trace.total_requests()
    );

    // 3. Build CAUSE (UCDP + RCMP + FiboR + SC) and run the lifecycle.
    let engine = SystemVariant::Cause.build_cost(&cfg)?;
    println!(
        "store: {} checkpoint slots ({} policy)\n",
        engine.store().capacity(),
        engine.store().policy_name()
    );
    let mut svc = UnlearningService::new(engine);

    for t in 1..=cfg.rounds {
        svc.ingest_round(&pop)?;
        for req in trace.at(t) {
            svc.submit(req.clone());
        }
        // Batched drain: the round's requests are coalesced into one
        // retrain plan per affected lineage (cfg.batch_policy, default
        // Coalesce) instead of one retrain pass per request.
        let windows_before = svc.batch_log.len();
        let served = svc.drain_batched()?;
        let m = &svc.engine().metrics;
        println!(
            "round {t}: served {served} requests in {} window(s) | \
             RSN this round {:>8} | store {}/{} slots",
            svc.batch_log.len() - windows_before,
            m.rsn_by_round.last().copied().unwrap_or(0),
            svc.engine().store().occupied(),
            svc.engine().store().capacity(),
        );
    }

    // 4. Receipts: what each batch window cost and what coalescing saved.
    println!("\nper-window receipts (first 5):");
    for b in svc.batch_log.iter().take(5) {
        println!(
            "  {} request(s): RSN {:>7}, {} lineage(s) retrained \
             ({} per-request retrains coalesced away), ~{:.1}s / {:.0} J on-device",
            b.requests, b.rsn, b.lineages_retrained, b.retrains_coalesced,
            b.est_seconds, b.est_joules
        );
    }

    let m = &svc.engine().metrics;
    println!(
        "\ntotals: RSN {} | energy {:.0} J | warm retrains {} | scratch {} | \
         retrains coalesced {} over {} window(s) | \
         checkpoints stored {} (replaced {}, rejected {})",
        m.total_rsn(),
        m.energy_joules,
        m.warm_retrains,
        m.scratch_retrains,
        m.retrains_coalesced,
        m.batches,
        m.ckpts_stored,
        m.ckpts_replaced,
        m.ckpts_rejected
    );

    // 5. Latency receipts: every served request records its queueing delay
    // (service-clock ticks) and whether the configured SLO was met. Under
    // the default Coalesce policy there is no SLO — switch to the
    // deadline-aware scheduler with `batch_policy = deadline` plus
    // `batch_slo = <ticks>` (config file / CLI) or
    // `ExperimentConfig::with_slo(ticks)`: the service then holds a window
    // open only while every queued request can still meet its SLO, so
    // coalescing is maximized subject to a per-request latency bound.
    // `batch_slo = 0` degenerates to the paper's FCFS service model;
    // `batch_slo = inf` to whole-queue coalescing at flush time.
    let delays = m.queue_delay_summary();
    println!(
        "latency: {} receipts | queueing delay p50 {:.1} / p99 {:.1} ticks | \
         {} SLO violations",
        m.latency.len(),
        delays.p50,
        delays.p99,
        m.slo_violations()
    );

    // 6. Planner cost model: everything the service just did rides on
    // index-accelerated planning. Pricing a window's merged plan
    // (`Engine::plan_lineage_rsn`, the probe battery admission re-runs on
    // every retry) is allocation-free: warm-start lookups hit the store's
    // (lineage, coverage)-ordered index in O(log slots), replay sizes come
    // from per-lineage prefix sums in O(log segments), and occupancy is a
    // counter. Replay *sets* are materialized — and checkpoint parameters
    // refcount-cloned, never copied — only when a plan executes.
    // `cargo bench --bench bench_scale` measures this against the
    // compiled-in naive-scan oracle and writes BENCH_scale.json:
    // `probe.speedup` (indexed vs scan pricing, same machine, gated >= 5x
    // in CI) and `e2e.gain` (requests/sec on a bursty coalesced-window
    // workload).

    // 7. Compressed checkpoint memory: by default the store meters C_m in
    // normalized slots (the paper's N_mem — what this demo printed above).
    // Two knobs make bytes the actual currency instead:
    //
    //   memory_budget_bytes = 268435456   # C_m in bytes; flips the store
    //                                     # to byte metering in one line
    //   store_mode = bytes                # (or set the meter explicitly;
    //                                     # `slots` restores the baseline)
    //   codec = sparse                    # checkpoint payload codec:
    //                                     # dense | sparse (default) | delta
    //
    // (equivalently `ExperimentConfig::with_byte_budget(bytes)` and
    // `with_codec(CodecMode::...)`). Tensor-carrying backends then store
    // each checkpoint as a bitmask+values sparse payload (dense fallback
    // when sparsity doesn't pay; `delta` additionally diffs against the
    // lineage's previous checkpoint), `Checkpoint::size_bytes` is the true
    // encoded size, and admission/eviction evict exactly as many victims
    // as those bytes require — so at keep=0.3 the same C_m holds ~3x the
    // checkpoints and replays fewer samples. Decoding happens lazily
    // through a per-plan cache: a checkpoint that warm-starts several
    // retrain steps decodes once. The accounting backend used in this
    // demo carries no tensors, so it keeps its paper-scale size formula.
    // `cargo bench --bench bench_compress` writes BENCH_compress.json:
    // `codec.keep30.ratio` (sparse compression at keep=0.3, gated >= 2x
    // in CI), `codec.*.{encode,decode}_mbps` (throughput;
    // `gate.decode_mbps` has a conservative floor), and `workload.*`
    // (slot- vs byte-metered checkpoint counts and RSN on the same C_m —
    // the byte meter must hold >=2x the checkpoints and cut RSN).

    // 8. Durability: edge devices reboot, and the deletion guarantee must
    // survive the reboot. Three config knobs control it:
    //
    //   durability    = off | log | log+spill
    //   persist_dir   = cause_persist      # MANIFEST.json, wal-*.log,
    //                                      # snapshot-*.bin live here
    //   compact_every = 512                # events between automatic
    //                                      # snapshot+truncate compactions
    //
    // With `durability = log` every service transition — submit, round
    // ingest, window execution, battery settle, carryover — is appended to
    // a CRC-framed write-ahead log *before* the call returns
    // (log-before-ack), and `SystemVariant::build_service` recovers the
    // pre-crash state from `persist_dir` on construction. `log+spill`
    // additionally spills encoded checkpoint payloads so store tensors
    // recover bit-exactly. Below: run a durable service against an
    // in-memory filesystem, "crash" it (drop it mid-run), and recover —
    // the receipts match to the byte.
    let fs = MemFs::new();
    let cfg2 = ExperimentConfig { users: 12, rounds: 3, shards: 4, ..Default::default() };
    let pop2 = common::population(&cfg2);
    let trace2 = RequestTrace::generate(
        &pop2,
        &TraceConfig::paper_default(3).with_prob(0.3),
    );
    let mut durable =
        UnlearningService::new(SystemVariant::Cause.build_cost(&cfg2)?);
    durable.attach_durability(Durability::mem(DurabilityMode::Log, fs.clone(), 0))?;
    for t in 1..=cfg2.rounds {
        durable.ingest_round(&pop2)?;
        for req in trace2.at(t) {
            durable.submit(req.clone());
        }
        durable.drain_batched()?;
    }
    let pre_crash = durable.state_receipt();
    let logged = durable.journal_events();
    drop(durable); // power loss

    let mut recovered =
        UnlearningService::new(SystemVariant::Cause.build_cost(&cfg2)?);
    let report =
        recovered.attach_durability(Durability::mem(DurabilityMode::Log, fs, 0))?;
    assert_eq!(recovered.state_receipt(), pre_crash, "recovery must be exact");
    println!(
        "\ndurability: {} events logged; recovery replayed {} (snapshot: {}) — \
         state receipt identical after the crash",
        logged, report.events_replayed, report.snapshot_loaded
    );

    // 9. Fleet mode: one config knob shards the whole service.
    //
    //   fleet_workers = 2     # N shard workers, each with its own engine,
    //                         # store, battery, planner and (with
    //                         # durability) WAL under persist_dir/shard-<k>/
    //
    // `SystemVariant::build_fleet` promotes the UCDP user→shard map into a
    // routing layer: every user's rounds and unlearning requests go to the
    // shard worker holding their data (sticky — a shard-controller shrink
    // only bumps the routing epoch, it never re-homes a known user), the
    // workers price their batch windows locally, and battery admission is
    // decided centrally from the quoted costs before any worker commits.
    // Per-shard receipts, metrics, batch logs, and journals merge
    // deterministically at the front-end. `cargo bench --bench bench_fleet`
    // measures the 2-worker scaling ratio and the merge overhead
    // (BENCH_fleet.json, gated in CI).
    let cfg3 = ExperimentConfig {
        users: 16,
        rounds: 3,
        shards: 4,
        fleet_workers: 2,
        ..Default::default()
    };
    let pop3 = common::population(&cfg3);
    let trace3 = RequestTrace::generate(
        &pop3,
        &TraceConfig::paper_default(11).with_prob(0.4),
    );
    let mut fleet = SystemVariant::Cause.build_fleet(&cfg3)?;
    let mut served = 0;
    for t in 1..=cfg3.rounds {
        fleet.ingest_round(&pop3)?;
        for req in trace3.at(t) {
            fleet.submit(req.clone());
        }
        served += fleet.drain_batched()?;
    }
    served += fleet.flush_batched()?;
    println!(
        "\nfleet: {} workers served {} requests | routing epoch {} | \
         audit seeds {:?}",
        fleet.workers(),
        served,
        fleet.epoch(),
        fleet.shard_seeds().iter().map(|s| format!("{s:#x}")).collect::<Vec<_>>()
    );

    // The keystone invariant: fleet_workers = 1 replays the unsharded
    // service byte-identically — same receipts, RSN, store stats — so
    // turning the fleet on is never a semantic change, only a scale-out.
    let cfg1 = ExperimentConfig { fleet_workers: 1, ..cfg3.clone() };
    let mut one = SystemVariant::Cause.build_fleet(&cfg1)?;
    let mut solo = SystemVariant::Cause.build_service(&cfg1)?;
    for t in 1..=cfg1.rounds {
        one.ingest_round(&pop3)?;
        solo.ingest_round(&pop3)?;
        for req in trace3.at(t) {
            one.submit(req.clone());
            solo.submit(req.clone());
        }
        one.drain_batched()?;
        solo.drain_batched()?;
    }
    one.flush_batched()?;
    solo.flush_batched()?;
    assert_eq!(
        one.state_receipt()?.to_pretty(),
        solo.state_receipt().to_pretty(),
        "fleet_workers=1 must replay the unsharded service byte-identically"
    );
    println!("fleet_workers=1 state receipt is byte-identical to the unsharded service");

    // 10. Open-loop load harness: how much deletion traffic can this
    // device actually sustain? `cause::load` drives a service with an
    // *open-loop* arrival schedule — requests arrive on the scenario's
    // clock whether or not the device kept up, the honest way to measure
    // saturation — and records every queueing delay in a log-bucketed
    // histogram (<=12.5% relative error per bucket, mergeable across
    // fleet shards). The corpus ships six seeded scenarios (GDPR
    // deletion storm, diurnal burst, heavy-tail user skew, satellite
    // contact windows, IoT fleet churn, adversarial oldest-segment
    // targeting), each an energy-bounded device on a harvest cycle; all
    // arrivals, energy flows, and counters run on logical ticks, so the
    // same seed reproduces the same report byte-for-byte. Per scenario,
    // `cargo bench --bench bench_load` sweeps the offered rate for the
    // highest rate at which every request met the SLO with no battery
    // carryover, and writes BENCH_load.json —
    // `gate.<scenario>_rps_at_slo` floors are ratcheted in CI by
    // bench_gate (per bench mode: CAUSE_BENCH_FAST changes the swept
    // grid, so the artifact is mode-stamped and only compared against
    // same-mode floors). Here: one light run of the diurnal-burst
    // scenario.
    let scenarios = cause::load::corpus();
    let sc = &scenarios[1]; // diurnal_burst
    let run = cause::load::OpenLoopCfg {
        offered_per_tick: 1.0,
        ticks: 12,
        tail_ticks: 64,
        seed: 0x10ad,
        obs: false,
    };
    let report = cause::load::run_open_loop(sc.as_ref(), &run)?;
    println!(
        "\nload [{}]: {} requests at {}/tick -> served {} | queueing delay \
         p50 {} / p99 {} / p999 {} ticks | slo_ok={} | trace digest {:016x}",
        sc.name(),
        report.submitted,
        run.offered_per_tick,
        report.served,
        report.p50(),
        report.p99(),
        report.p999(),
        report.slo_ok,
        report.trace_digest
    );

    // 11. Crash-proof fleet durability: three more knobs make the fleet
    // survive power loss and shard death.
    //
    //   durability         = log+fsync  # WAL + an fsync barrier per event
    //                                   # (shorthand for `fsync = always`)
    //   fsync_group_commit = true       # amortize: one barrier per sealed
    //                                   # commit scope (round ingest /
    //                                   # window drain), not one per event
    //   ship_to_peer       = true       # each fleet worker streams its
    //                                   # sealed WAL frames to peer shard
    //                                   # (k+1) % N, with bounded retry
    //
    // Every WAL frame's CRC folds in the previous frame's CRC, so a torn
    // or reordered tail is detected structurally, and recovery truncates
    // to the last chain-consistent barrier. With shipping on, a shard can
    // die outright — `failover(k)` rebuilds it from the *peer's* copy of
    // its log, re-homes routing under a bumped epoch, and replays every
    // acknowledged obligation. The fault-injection suite
    // (`tests/durability.rs`, `tests/fleet_failover.rs`) crashes the log
    // at every byte offset and drops/duplicates/reorders shipping traffic
    // to prove receipt-identical recovery; `cargo bench --bench
    // bench_persist` pins the fsync append floor and the group-commit
    // amortization ratio in BENCH_persist.json. Below: a durable 2-worker
    // fleet with group-commit barriers and shipping, a shard killed
    // mid-run, and the failover that loses nothing.
    let mut dfleet = SystemVariant::Cause.build_fleet(&cfg3)?;
    dfleet.attach_durability(
        (0..cfg3.fleet_workers)
            .map(|_| {
                Durability::mem(DurabilityMode::Log, MemFs::new(), 0)
                    .with_fsync(FsyncPolicy::GroupCommit)
            })
            .collect(),
    )?;
    dfleet.enable_log_shipping()?;
    for t in 1..=cfg3.rounds {
        dfleet.ingest_round(&pop3)?;
        for req in trace3.at(t) {
            dfleet.submit(req.clone());
        }
        dfleet.drain_batched()?;
    }
    dfleet.sync_journals()?; // final group-commit barrier + ship the tail
    println!();
    for (k, (receipt, log_seq)) in dfleet.shipping_states()?.iter().enumerate() {
        let r = receipt.as_ref().expect("shipping enabled");
        println!(
            "shard {k}: WAL at seq {log_seq}, shipped through {} to peer \
             ({} pending)",
            r.shipped_seq, r.pending
        );
    }
    let epoch_before = dfleet.epoch();
    dfleet.kill_worker(0)?;
    assert!(dfleet.drain_batched().is_err(), "a dead shard fails loudly, never silently");
    let report = dfleet.failover(0)?;
    println!(
        "failover: shard 0 rebuilt from shard 1's shipped log — {} event(s) \
         replayed (snapshot: {}), routing epoch {} -> {}",
        report.events_replayed,
        report.snapshot_loaded,
        epoch_before,
        dfleet.epoch()
    );
    dfleet.ingest_round(&pop3)?;
    dfleet.drain_batched()?;
    dfleet.sync_journals()?;
    println!(
        "post-failover: the rebuilt shard serves traffic and ships its log \
         again — zero acknowledged obligations lost"
    );

    // 12. Chaos soak: the durability story above, attacked continuously.
    // `cause::load::chaos` drives any corpus scenario open-loop over a
    // durable, log-shipping fleet while a seeded `ChaosPlan` injects the
    // faults the system claims to survive — worker kills with failover,
    // transport drop/dup/stale bursts, injected fsync failures, battery
    // collapse, and full crash-restart-recover cycles — and audits an
    // invariant sweep at every barrier: no acknowledged obligation lost,
    // journal sequences never regress, shipping watermarks catch the log
    // head, each peer replica byte-equals the source's durable state and
    // stays bounded by its live (post-compaction) WAL, and every
    // recovery lands on the exact pre-fault logical receipt. Set
    // `spool: true` to ship over the file-backed spool (`FileSpool` —
    // frames survive process death on the peer's disk; production fleets
    // get the same via the `ship_spool_dir` config knob), and everything
    // is seeded, so a failing (scenario, seed) pair replays exactly.
    // `cargo run --release --bin soak` runs the wide multi-seed sweep CI
    // gates on (SOAK_report.json); here, one small plan:
    use cause::load::{run_chaos, ChaosCfg, ChaosPlan, FaultClass};
    let plan = ChaosPlan::seeded(0xc4a0, 24, &FaultClass::ALL);
    let chaos_cfg = ChaosCfg { ticks: 24, check_every: 6, spool: true, ..ChaosCfg::default() };
    let report = run_chaos(scenarios[0].as_ref(), &plan, &chaos_cfg)?;
    assert!(report.ok(), "chaos violations: {:?}", report.violations);
    println!(
        "\nchaos [{}]: {} fault(s) over {} ticks ({} failover(s), {} \
         restart(s), {} barrier sweeps) — served {}/{} submitted, \
         replicas {:?} bytes vs live {:?}, zero invariant violations",
        report.scenario,
        report.faults.len(),
        report.ticks,
        report.failovers,
        report.restarts,
        report.barriers,
        report.served,
        report.submitted,
        report.replica_bytes,
        report.live_bytes
    );

    // 13. Observability: where did the run's time go? Two config knobs
    // turn on the deterministic span tracer:
    //
    //   obs     = true          # per-shard ring-buffer span tracing:
    //                           # plan→price→admit→retrain→seal→ship,
    //                           # zero allocation on the hot path
    //   obs_dir = cause_traces  # `cause run` writes <prefix>_trace.json
    //                           # (Chrome trace_event — load it in
    //                           # chrome://tracing or Perfetto) and
    //                           # <prefix>_events.jsonl; implies obs
    //
    // Spans carry virtual (tick-derived) timestamps, so the same seed
    // exports a byte-identical trace, and tracing is observation-only:
    // receipts and metrics do not move by a byte when it is on (both
    // properties are pinned in `tests/obs_telemetry.rs`, and `cargo
    // bench --bench bench_obs` gates the wall-clock overhead <= 5% in
    // CI). Independently of the tracer, every service exposes a metrics
    // registry — named counters/gauges/histograms unifying run metrics,
    // journal fsync stats, and ship-retry diagnostics, merged across
    // fleet shards — which is where `LoadReport::telemetry` comes from.
    // The `obs` binary (`cargo run --bin obs -- run_trace.json`) folds
    // any exported trace into the per-phase tick-budget table printed
    // below.
    let traced = cause::load::run_open_loop(
        sc.as_ref(),
        &cause::load::OpenLoopCfg { obs: true, ..run },
    )?;
    println!("\nobs [{}]: telemetry {}", sc.name(), traced.telemetry);
    let trace = traced.trace.expect("obs run carries a Chrome-trace export");
    let (spans, markers) = cause::obs::budget::spans_from_chrome(&trace)
        .map_err(anyhow::Error::msg)?;
    print!("{}", cause::obs::budget::render(&cause::obs::budget::compute(&spans), &markers));
    Ok(())
}
