//! Satellite scenario: exact unlearning on an energy-harvesting device.
//!
//! An AI cubesat captures imagery each orbit (a training round), and
//! sensitive captures must be forgotten on demand (the paper's motivating
//! wartime-imagery example). The battery cannot always cover a retrain, so
//! the service defers requests until solar harvest catches up — the
//! experiment shows why CAUSE's low-RSN retraining is what makes exact
//! unlearning feasible at all in this envelope.
//!
//! ```bash
//! cargo run --release --example satellite_energy
//! ```

use cause::config::ExperimentConfig;
use cause::coordinator::system::SystemVariant;
use cause::data::trace::{RequestTrace, TraceConfig};
use cause::experiments::common;
use cause::sim::device::AI_CUBESAT;
use cause::sim::Battery;
use cause::unlearning::UnlearningService;

const ORBIT_SECS: f64 = 5_400.0; // ~90 minutes

fn run_system(variant: SystemVariant) -> anyhow::Result<()> {
    let cfg = ExperimentConfig {
        users: 30,
        rounds: 8,
        shards: 4,
        unlearn_prob: 0.3,
        model: cause::config::profiles::MOBILENETV2, // edge-sized backbone
        ..Default::default()
    }
    .with_memory_gb(AI_CUBESAT.memory_bytes as f64 / (1u64 << 30) as f64);

    let pop = common::population(&cfg);
    let trace = RequestTrace::generate(
        &pop,
        &TraceConfig::paper_default(13).with_prob(cfg.unlearn_prob),
    );

    let engine = variant.build_cost(&cfg)?;
    let mut svc = UnlearningService::new(engine).with_battery(Battery::new(&AI_CUBESAT));

    let mut deferred_total = 0usize;
    for orbit in 1..=cfg.rounds {
        svc.harvest(ORBIT_SECS);
        svc.ingest_round(&pop)?;
        for req in trace.at(orbit) {
            svc.submit(req.clone());
        }
        let before = svc.pending();
        svc.drain()?;
        let deferred = svc.pending();
        deferred_total += deferred;
        println!(
            "  orbit {orbit}: {} new requests, {} served, {} deferred | \
             battery {:>5.1}% | RSN so far {}",
            trace.at(orbit).len(),
            before - deferred,
            deferred,
            svc.battery().map(|b| b.soc() * 100.0).unwrap_or(100.0),
            svc.engine().metrics.total_rsn()
        );
        // Idle harvest between request bursts.
        svc.harvest(ORBIT_SECS);
        svc.drain()?;
    }
    let m = &svc.engine().metrics;
    println!(
        "  == {}: total RSN {} | energy {:.0} J (battery {:.0} J) | \
         deferral events {} ({} receipts) | brownouts {}\n",
        variant.display(),
        m.total_rsn(),
        m.energy_joules,
        AI_CUBESAT.battery_joules,
        deferred_total,
        // One receipt per starvation episode (not per drain poll).
        svc.log.iter().filter(|r| r.deferred).count(),
        svc.battery().map(|b| b.brownouts).unwrap_or(0)
    );
    Ok(())
}

fn main() -> anyhow::Result<()> {
    println!(
        "cubesat envelope: {} MB model memory, {:.0} Wh battery, {:.0} W harvest\n",
        AI_CUBESAT.memory_bytes / (1024 * 1024),
        AI_CUBESAT.battery_joules / 3600.0,
        AI_CUBESAT.harvest_watts
    );
    for v in [SystemVariant::Cause, SystemVariant::Sisa] {
        println!("{}:", v.display());
        run_system(v)?;
    }
    Ok(())
}
