//! Satellite scenario: exact unlearning on an energy-harvesting device
//! under hard contact-window deadlines.
//!
//! An AI cubesat captures imagery each orbit (a training round), and
//! sensitive captures must be forgotten on demand (the paper's motivating
//! wartime-imagery example). Two constraints shape the service:
//!
//! * **Deadlines** — ground contact happens once per orbit, so an
//!   unlearning request must be honored within one orbit
//!   (`batch_policy = deadline`, `batch_slo = 1` tick = 1 orbit). The
//!   planner holds the queue just long enough to coalesce every request
//!   that arrives within the window, then retrains each affected lineage
//!   once — maximum coalescing *subject to* the contact deadline.
//! * **Energy** — the battery cannot always cover a retrain. Admission
//!   reserves the window's true merged plan cost (one resolver pass) and
//!   splits the plan at lineage granularity when only a prefix is
//!   affordable; the rest replays after solar harvest catches up.
//!
//! ```bash
//! cargo run --release --example satellite_energy
//! ```

use cause::config::ExperimentConfig;
use cause::coordinator::system::SystemVariant;
use cause::data::trace::{RequestTrace, TraceConfig};
use cause::experiments::common;
use cause::sim::device::AI_CUBESAT;
use cause::sim::Battery;

const ORBIT_SECS: f64 = 5_400.0; // ~90 minutes

/// One orbit of contact: the request deadline, in service-clock ticks.
const CONTACT_SLO_TICKS: u64 = 1;

fn run_system(variant: SystemVariant) -> anyhow::Result<()> {
    let cfg = ExperimentConfig {
        users: 30,
        rounds: 8,
        shards: 4,
        unlearn_prob: 0.3,
        model: cause::config::profiles::MOBILENETV2, // edge-sized backbone
        ..Default::default()
    }
    .with_memory_gb(AI_CUBESAT.memory_bytes as f64 / (1u64 << 30) as f64)
    // CAUSE honors the contact-window deadline; the baselines stay pinned
    // to their papers' FCFS service model via SystemVariant::batch_policy.
    .with_slo(CONTACT_SLO_TICKS);

    let pop = common::population(&cfg);
    let trace = RequestTrace::generate(
        &pop,
        &TraceConfig::paper_default(13).with_prob(cfg.unlearn_prob),
    );

    let mut svc = variant
        .build_service(&cfg)?
        .with_battery(Battery::new(&AI_CUBESAT));
    println!("  service policy: {}", svc.planner().policy.display());

    for orbit in 1..=cfg.rounds {
        svc.harvest(ORBIT_SECS);
        svc.ingest_round(&pop)?; // advances the service clock one orbit
        svc.drain_batched()?; // last orbit's window hits its deadline here
        for req in trace.at(orbit) {
            svc.submit(req.clone());
        }
        svc.drain_batched()?;
        println!(
            "  orbit {orbit}: {} new requests, {} queued for next contact, \
             {} awaiting energy | battery {:>5.1}% | RSN so far {}",
            trace.at(orbit).len(),
            svc.pending(),
            svc.carryover_requests(),
            svc.battery().map(|b| b.soc() * 100.0).unwrap_or(100.0),
            svc.engine().metrics.total_rsn()
        );
        // Idle harvest between request bursts.
        svc.harvest(ORBIT_SECS);
        svc.drain_batched()?;
    }
    // Decommission pass: serve the final window and let harvest fund any
    // battery-deferred replay.
    svc.advance(CONTACT_SLO_TICKS);
    svc.flush_batched()?;
    for _ in 0..8 {
        // carryover_lineages, not carryover_requests: a battery-split
        // window parks its unfunded lineage share with zero requests
        // (they were served and accounted with the executed prefix).
        if svc.carryover_lineages() == 0 && svc.pending() == 0 {
            break;
        }
        svc.harvest(ORBIT_SECS);
        svc.advance(1);
        svc.flush_batched()?;
    }

    let m = &svc.engine().metrics;
    let delays = m.queue_delay_summary();
    println!(
        "  == {}: total RSN {} | energy {:.0} J (battery {:.0} J) | \
         {} windows, {} retrains coalesced | queue delay p50 {:.1} / p99 {:.1} \
         orbits, {} of {} receipts met the {CONTACT_SLO_TICKS}-orbit SLO | \
         deferral receipts {} | brownouts {}\n",
        variant.display(),
        m.total_rsn(),
        m.energy_joules,
        AI_CUBESAT.battery_joules,
        m.batches,
        m.retrains_coalesced,
        delays.p50,
        delays.p99,
        m.latency.len() as u64 - m.slo_violations(),
        m.latency.len(),
        svc.batch_log.iter().filter(|b| b.deferred).count(),
        svc.battery().map(|b| b.brownouts).unwrap_or(0)
    );
    Ok(())
}

fn main() -> anyhow::Result<()> {
    println!(
        "cubesat envelope: {} MB model memory, {:.0} Wh battery, {:.0} W harvest, \
         contact window = 1 orbit\n",
        AI_CUBESAT.memory_bytes / (1024 * 1024),
        AI_CUBESAT.battery_joules / 3600.0,
        AI_CUBESAT.harvest_watts
    );
    for v in [SystemVariant::Cause, SystemVariant::Sisa] {
        println!("{}:", v.display());
        run_system(v)?;
    }
    Ok(())
}
