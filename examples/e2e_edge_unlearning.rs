//! End-to-end driver: the full three-layer stack on a real workload.
//!
//! Everything below runs through the AOT artifacts on the PJRT CPU client —
//! Python is not involved. Three phases:
//!
//!   A. *Training sanity*: train the proxy model for a few hundred steps on
//!      the synthetic corpus and log the loss curve (proves L1 Pallas
//!      kernels + L2 train step + L3 runtime compose).
//!   B. *Full system*: run CAUSE and SISA with the real trainer over T
//!      rounds of data arrival + unlearning requests; report per-round
//!      ensemble accuracy, RSN, and store behaviour.
//!   C. *Unlearning effect*: check that retraining actually moved the
//!      affected sub-model (parameters change, accuracy survives).
//!
//! Results from this run are recorded in EXPERIMENTS.md §E2E.
//!
//! ```bash
//! make artifacts && cargo run --release --example e2e_edge_unlearning
//! ```

use std::rc::Rc;
use std::sync::Arc;
use std::time::Instant;

use cause::config::ExperimentConfig;
use cause::coordinator::engine::EvalPolicy;
use cause::coordinator::system::SystemVariant;
use cause::data::catalog::CIFAR10;
use cause::data::dataset::{EdgePopulation, PopulationConfig};
use cause::data::trace::{RequestTrace, TraceConfig};
use cause::runtime::{Runtime, TrainSession};
use cause::training::{PjrtTrainer, PjrtTrainerConfig};

const VARIANT: &str = "mobilenetv2_c10";

fn main() -> anyhow::Result<()> {
    let dir = std::env::var("CAUSE_ARTIFACTS").unwrap_or_else(|_| "artifacts".into());
    let rt = Rc::new(Runtime::new(&dir)?);
    println!("PJRT platform: {} | artifacts: {}", rt.platform(), dir);

    // ---------------------------------------------------------------- A —
    println!("\n== Phase A: training sanity (loss curve) ==");
    let corpus = 3_000u64;
    let pop = Arc::new(EdgePopulation::generate(PopulationConfig {
        spec: CIFAR10.scaled(corpus),
        users: 40,
        rounds: 5,
        size_sigma: 0.8,
        label_alpha: 0.5,
        arrival_prob: 0.8,
        seed: 42,
    }));
    let mut sess = TrainSession::init(rt.clone(), VARIANT, 1)?;
    let (txs, tys) = pop.materialize_test(256, 9);
    let t0 = Instant::now();
    let mut step = 0usize;
    for epoch in 0..3 {
        for r in 1..=5 {
            for b in pop.blocks_at(r) {
                let (xs, ys) = pop.materialize(b, b.samples as usize);
                let bs = sess.batch_size();
                let fd = sess.feature_dim();
                let mut row = 0;
                while row < ys.len() {
                    let take = bs.min(ys.len() - row);
                    let loss =
                        sess.step(&xs[row * fd..(row + take) * fd], &ys[row..row + take], 0.05)?;
                    row += take;
                    step += 1;
                    if step % 25 == 0 {
                        println!("  step {step:>4} (epoch {epoch}): loss {loss:.4}");
                    }
                }
            }
        }
    }
    let train_secs = t0.elapsed().as_secs_f64();
    // Final accuracy of the single model.
    let mut correct = 0usize;
    let (bs, fd) = (sess.batch_size(), sess.feature_dim());
    let mut r = 0;
    while r < tys.len() {
        let take = bs.min(tys.len() - r);
        for (row, y) in sess.logits(&txs[r * fd..(r + take) * fd], take)?.iter().zip(&tys[r..]) {
            if cause::coordinator::aggregate::argmax(row) == *y as usize {
                correct += 1;
            }
        }
        r += take;
    }
    let stats = rt.stats();
    println!(
        "  {} steps in {:.1}s ({:.1} steps/s, {:.2} ms/step PJRT) -> accuracy {:.3}",
        step,
        train_secs,
        step as f64 / train_secs,
        stats.execute_secs / stats.executions.max(1) as f64 * 1e3,
        correct as f64 / tys.len() as f64
    );

    // ---------------------------------------------------------------- B —
    println!("\n== Phase B: CAUSE vs SISA, real training + unlearning ==");
    let mut base = ExperimentConfig {
        users: 40,
        rounds: 5,
        shards: 4,
        unlearn_prob: 0.25,
        ..Default::default()
    };
    base.dataset = CIFAR10.scaled(corpus);
    if let Ok(k) = std::env::var("CAUSE_E2E_PRUNE_KEEP") {
        base.prune_keep = k.parse().unwrap_or(base.prune_keep);
    }
    for variant in [SystemVariant::Cause, SystemVariant::Sisa] {
        let pop = Arc::new(EdgePopulation::generate(PopulationConfig {
            spec: base.dataset.clone(),
            users: base.users,
            rounds: base.rounds,
            size_sigma: 0.8,
            label_alpha: 0.5,
            arrival_prob: 0.8,
            seed: base.seed,
        }));
        let trace = RequestTrace::generate(
            &pop,
            &TraceConfig::paper_default(base.seed ^ 0x7ace).with_prob(base.unlearn_prob),
        );
        let trainer = PjrtTrainer::new(
            rt.clone(),
            pop.clone(),
            PjrtTrainerConfig {
                variant: VARIANT.into(),
                max_epochs: 2,
                lr: 0.05,
                test_samples: 256,
                seed: base.seed,
            },
            base.shards,
            variant.schedule(&base).final_keep(),
        )?;
        let mut engine =
            variant.build_with_trainer(&base, Box::new(trainer), EvalPolicy::EveryRound)?;
        let t0 = Instant::now();
        engine.run_trace(&pop, &trace)?;
        let secs = t0.elapsed().as_secs_f64();
        let m = &engine.metrics;
        println!("  {} ({:.1}s wall):", variant.display(), secs);
        for (i, acc) in m.accuracy_by_round.iter().enumerate() {
            println!(
                "    round {}: accuracy {}  RSN {:>6}  requests {}",
                i + 1,
                acc.map(|a| format!("{a:.3}")).unwrap_or_else(|| "-".into()),
                m.rsn_by_round[i],
                m.requests_by_round[i]
            );
        }
        println!(
            "    totals: RSN {} | energy {:.0} J | warm {} scratch {} | \
             store {}/{} ({} replaced, {} rejected)",
            m.total_rsn(),
            m.energy_joules,
            m.warm_retrains,
            m.scratch_retrains,
            engine.store().occupied(),
            engine.store().capacity(),
            m.ckpts_replaced,
            m.ckpts_rejected
        );
    }

    // ---------------------------------------------------------------- C —
    println!("\n== Phase C: unlearning moves the model ==");
    let pop_c = Arc::new(EdgePopulation::generate(PopulationConfig {
        spec: CIFAR10.scaled(800),
        users: 8,
        rounds: 2,
        size_sigma: 0.5,
        label_alpha: 1.0,
        arrival_prob: 1.0,
        seed: 5,
    }));
    let trainer = PjrtTrainer::new(
        rt.clone(),
        pop_c.clone(),
        PjrtTrainerConfig { variant: VARIANT.into(), max_epochs: 2, ..Default::default() },
        2,
        0.3,
    )?;
    let cfg_c = ExperimentConfig {
        users: 8,
        rounds: 2,
        shards: 2,
        dataset: CIFAR10.scaled(800),
        ..Default::default()
    };
    let mut engine =
        SystemVariant::Cause.build_with_trainer(&cfg_c, Box::new(trainer), EvalPolicy::Never)?;
    engine.run_round(&pop_c)?;
    engine.run_round(&pop_c)?;
    let before: Vec<_> = engine.store().iter().map(|c| c.id).collect();
    // Unlearn the first user's newest block.
    let user = pop_c.blocks_at(2)[0].user;
    let block = pop_c.blocks_at(2)[0].id;
    let req = cause::data::trace::UnlearnRequest {
        round: 2,
        user,
        arrival_tick: 2,
        parts: vec![(block, pop_c.block(block).unwrap().samples / 2)],
    };
    let out = engine.process_request(&req)?;
    println!(
        "  request removed {} samples -> RSN {}, {} lineage(s), {} ckpt(s) invalidated",
        req.total_samples(),
        out.rsn,
        out.lineages_retrained,
        out.ckpts_invalidated
    );
    assert!(out.rsn > 0, "retraining must replay something");
    let after: Vec<_> = engine.store().iter().map(|c| c.id).collect();
    assert_ne!(before, after, "checkpoint set should have changed");
    println!("  checkpoint set changed; unlearned sub-model retrained. OK");

    let stats = rt.stats();
    println!(
        "\nruntime totals: {} executions, {:.1}s execute, {:.1}s transfer, {} compiles ({:.1}s)",
        stats.executions, stats.execute_secs, stats.transfer_secs, stats.compiles, stats.compile_secs
    );
    Ok(())
}
